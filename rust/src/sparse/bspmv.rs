//! BSpMV — blocked sparse matrix-vector multiply (paper §5.2, Alg. 4).
//!
//! The routed FFN's execution strategy: iterate over weight blocks, gather
//! the tokens that activated each block, run dense GEMMs, scatter results
//! back.  This is the rust-native twin of
//! `python/compile/kernels/routed_ffn.py` (which uses the static-capacity
//! TPU formulation); here shapes are dynamic, as in the paper's CUDA code.

use super::grad;
use super::matrix::Matrix;

/// Router output for a token batch.
#[derive(Debug, Clone)]
pub struct Routing {
    /// `[nt][G]` activation mask.
    pub mask: Vec<Vec<bool>>,
    /// `[nt][G]` gate value (softmax over selected scores * G').
    pub gate: Vec<Vec<f32>>,
    pub g: usize,
    pub g_active: usize,
}

/// Compute routing from router scores (top-G' by |score|, gated by a
/// softmax over the selected scores — matches the L1 kernel semantics).
pub fn route(scores: &Matrix, g_active: usize) -> Routing {
    let nt = scores.rows;
    let g = scores.cols;
    assert!(g_active >= 1 && g_active <= g);
    let mut mask = vec![vec![false; g]; nt];
    let mut gate = vec![vec![0.0f32; g]; nt];
    for t in 0..nt {
        let row = scores.row(t);
        // top-G' by |score|, ties by lower index.
        let mut order: Vec<usize> = (0..g).collect();
        order.sort_by(|&a, &b| {
            row[b].abs().total_cmp(&row[a].abs()).then(a.cmp(&b))
        });
        let sel = &order[..g_active];
        let mx = sel.iter().map(|&j| row[j]).fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for &j in sel {
            denom += (row[j] - mx).exp();
        }
        for &j in sel {
            mask[t][j] = true;
            gate[t][j] = (row[j] - mx).exp() / denom.max(1e-30) * g_active as f32;
        }
    }
    Routing { mask, gate, g, g_active }
}

/// One block's contribution (paper Alg. 4 lines 2-5): the activated
/// token list and their output rows `relu(X_g W_I[g]) * gate @ W_O[g]`,
/// or `None` when no token activated the block.  Shared by the
/// sequential [`routed_ffn`] and the parallel
/// [`crate::sparse::mha::routed_ffn_par`], so the two execution paths
/// stay bit-identical by construction.
pub fn block_partial(
    gi: usize,
    x: &Matrix,
    w_i: &Matrix,
    w_o: &Matrix,
    routing: &Routing,
) -> Option<(Vec<usize>, Matrix)> {
    let nt = x.rows;
    let d = x.cols;
    let dg = w_i.cols / routing.g;
    // Select tokens (Alg. 4 lines 2-3) — the paper's index_get.
    let tokens: Vec<usize> = (0..nt).filter(|&t| routing.mask[t][gi]).collect();
    if tokens.is_empty() {
        return None;
    }
    // Gather X_g.
    let mut xg = Matrix::zeros(tokens.len(), d);
    for (r, &t) in tokens.iter().enumerate() {
        xg.row_mut(r).copy_from_slice(x.row(t));
    }
    // Block of W_I: columns [gi*dg, (gi+1)*dg).
    let mut wi_g = Matrix::zeros(d, dg);
    for r in 0..d {
        wi_g.row_mut(r)
            .copy_from_slice(&w_i.row(r)[gi * dg..(gi + 1) * dg]);
    }
    // Inner projection + ReLU (line 4), gated.
    let mut h = xg.matmul(&wi_g).relu();
    for (r, &t) in tokens.iter().enumerate() {
        let gate = routing.gate[t][gi];
        for v in h.row_mut(r) {
            *v *= gate;
        }
    }
    // Block of W_O: rows [gi*dg, (gi+1)*dg).
    let wo_g = Matrix::from_vec(
        dg,
        d,
        w_o.data[gi * dg * d..(gi + 1) * dg * d].to_vec(),
    );
    // Outer projection (line 5); the caller scatters — paper's index_put.
    Some((tokens, h.matmul(&wo_g)))
}

/// One block's backward, the unit both [`routed_ffn_backward`] and the
/// parallel [`crate::sparse::mha::routed_ffn_backward_par`] dispatch:
/// recompute the block forward (gather + inner GEMM + ReLU), then push
/// `dY` back through it.  The routing (mask and gate values) is treated
/// as a constant, matching the forward's non-differentiable top-G'
/// selection.  Returns `(tokens, dX_g, dW_I[g], dW_O[g])`, or `None`
/// when no token activated the block.
pub fn block_backward(
    gi: usize,
    x: &Matrix,
    w_i: &Matrix,
    w_o: &Matrix,
    routing: &Routing,
    dy: &Matrix,
) -> Option<(Vec<usize>, Matrix, Matrix, Matrix)> {
    let nt = x.rows;
    let d = x.cols;
    let dg = w_i.cols / routing.g;
    let tokens: Vec<usize> = (0..nt).filter(|&t| routing.mask[t][gi]).collect();
    if tokens.is_empty() {
        return None;
    }
    // Gather X_g and dY_g.
    let mut xg = Matrix::zeros(tokens.len(), d);
    let mut dyg = Matrix::zeros(tokens.len(), d);
    for (r, &t) in tokens.iter().enumerate() {
        xg.row_mut(r).copy_from_slice(x.row(t));
        dyg.row_mut(r).copy_from_slice(dy.row(t));
    }
    // Block slices of W_I (columns) and W_O (rows), as in the forward.
    let mut wi_g = Matrix::zeros(d, dg);
    for r in 0..d {
        wi_g.row_mut(r)
            .copy_from_slice(&w_i.row(r)[gi * dg..(gi + 1) * dg]);
    }
    let wo_g = Matrix::from_vec(
        dg,
        d,
        w_o.data[gi * dg * d..(gi + 1) * dg * d].to_vec(),
    );
    // Recompute the hidden activations (recompute-based backward: the
    // forward keeps no per-block caches).
    let h = xg.matmul(&wi_g).relu();
    let mut hg = h.clone();
    for (r, &t) in tokens.iter().enumerate() {
        let gate = routing.gate[t][gi];
        for v in hg.row_mut(r) {
            *v *= gate;
        }
    }
    // dW_O[g] = (h * gate)^T dY_g ;  d(h*gate) = dY_g W_O[g]^T.
    let dwo_g = grad::matmul_dw(&hg, &dyg);
    let mut dh = grad::matmul_dx(&dyg, &wo_g);
    for (r, &t) in tokens.iter().enumerate() {
        let gate = routing.gate[t][gi];
        for v in dh.row_mut(r) {
            *v *= gate;
        }
    }
    let dpre = grad::relu_backward(&h, &dh);
    // dW_I[g] = X_g^T dpre ;  dX_g = dpre W_I[g]^T.
    let dwi_g = grad::matmul_dw(&xg, &dpre);
    let dxg = grad::matmul_dx(&dpre, &wi_g);
    Some((tokens, dxg, dwi_g, dwo_g))
}

/// Backward of [`routed_ffn`]: per-block weight gradients accumulated
/// along the same [`Routing`] the forward used, plus the scattered input
/// gradient.  Returns `(dx, dw_i, dw_o)`.
pub fn routed_ffn_backward(
    x: &Matrix,
    w_i: &Matrix,
    w_o: &Matrix,
    routing: &Routing,
    dy: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    let nt = x.rows;
    let d = x.cols;
    assert_eq!(w_i.cols % routing.g, 0);
    assert_eq!(dy.rows, nt, "dY/X row mismatch");
    assert_eq!(dy.cols, d, "dY/X col mismatch");
    let dg = w_i.cols / routing.g;
    let mut dx = Matrix::zeros(nt, d);
    let mut dwi = Matrix::zeros(w_i.rows, w_i.cols);
    let mut dwo = Matrix::zeros(w_o.rows, w_o.cols);
    for gi in 0..routing.g {
        if let Some((tokens, dxg, dwi_g, dwo_g)) =
            block_backward(gi, x, w_i, w_o, routing, dy)
        {
            scatter_block_grads(
                &mut dx, &mut dwi, &mut dwo, gi, dg, &tokens, &dxg, &dwi_g, &dwo_g,
            );
        }
    }
    (dx, dwi, dwo)
}

/// Merge one block's backward outputs into the full-size gradient
/// buffers (ascending-block call order keeps the token scatter-add
/// deterministic; the W_I/W_O slices are disjoint per block).  Shared
/// with the parallel reduce in `sparse::mha`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scatter_block_grads(
    dx: &mut Matrix,
    dwi: &mut Matrix,
    dwo: &mut Matrix,
    gi: usize,
    dg: usize,
    tokens: &[usize],
    dxg: &Matrix,
    dwi_g: &Matrix,
    dwo_g: &Matrix,
) {
    for (r, &t) in tokens.iter().enumerate() {
        for (o, &g) in dx.row_mut(t).iter_mut().zip(dxg.row(r)) {
            *o += g;
        }
    }
    let d = dwi.rows;
    for r in 0..d {
        dwi.row_mut(r)[gi * dg..(gi + 1) * dg].copy_from_slice(dwi_g.row(r));
    }
    for r in 0..dg {
        dwo.row_mut(gi * dg + r).copy_from_slice(dwo_g.row(r));
    }
}

/// Routed FFN via BSpMV (paper Alg. 4).
///
/// `w_i`: `[d, D]` split into G column blocks; `w_o`: `[D, d]` split into G
/// row blocks.  For each block g: gather tokens with `mask[t][g]`, compute
/// `relu(X_g W_I[g]) * gate` then `@ W_O[g]`, scatter-add into Y.
pub fn routed_ffn(x: &Matrix, w_i: &Matrix, w_o: &Matrix, routing: &Routing) -> Matrix {
    let nt = x.rows;
    let d = x.cols;
    assert_eq!(w_i.cols % routing.g, 0);
    let mut y = Matrix::zeros(nt, d);
    for gi in 0..routing.g {
        if let Some((tokens, yg)) = block_partial(gi, x, w_i, w_o, routing) {
            for (r, &t) in tokens.iter().enumerate() {
                for (o, &v) in y.row_mut(t).iter_mut().zip(yg.row(r)) {
                    *o += v;
                }
            }
        }
    }
    y
}

/// Dense FFN baseline with the same gating (what BSpMV must equal).
pub fn dense_gated_ffn(
    x: &Matrix,
    w_i: &Matrix,
    w_o: &Matrix,
    routing: &Routing,
) -> Matrix {
    let dd = w_i.cols;
    let g = routing.g;
    let dg = dd / g;
    let h = x.matmul(w_i).relu();
    let mut hg = h;
    for t in 0..x.rows {
        for gi in 0..g {
            let gate = routing.gate[t][gi];
            for c in gi * dg..(gi + 1) * dg {
                *hg.at_mut(t, c) *= gate;
            }
        }
    }
    hg.matmul(w_o)
}

/// FLOPs of the routed FFN (forward) — `beta` of the dense cost.
pub fn routed_flops(nt: usize, d: usize, dd: usize, g: usize, g_active: usize) -> u64 {
    // per active (token, block): 2*d*dg + 2*dg*d
    let dg = (dd / g) as u64;
    (nt as u64) * (g_active as u64) * 4 * (d as u64) * dg
}

/// FLOPs of the dense FFN (forward).
pub fn dense_flops(nt: usize, d: usize, dd: usize) -> u64 {
    4 * (nt as u64) * (d as u64) * (dd as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::rng::Rng;

    #[test]
    fn bspmv_equals_dense_gated_ffn() {
        check(25, |g| {
            let nt = g.usize_in(1, 32);
            let d = g.usize_in(1, 12);
            let gg = *g.pick(&[2usize, 4, 8]);
            let dg = g.usize_in(1, 6);
            let dd = gg * dg;
            let ga = g.usize_in(1, gg);
            let mut rng = g.rng().fork();
            let x = Matrix::randn(nt, d, 1.0, &mut rng);
            let wi = Matrix::randn(d, dd, 0.3, &mut rng);
            let wo = Matrix::randn(dd, d, 0.3, &mut rng);
            let scores = Matrix::randn(nt, gg, 1.0, &mut rng);
            let routing = route(&scores, ga);
            let y1 = routed_ffn(&x, &wi, &wo, &routing);
            let y2 = dense_gated_ffn(&x, &wi, &wo, &routing);
            prop_assert(
                y1.max_abs_diff(&y2) < 1e-4,
                format!("diff {}", y1.max_abs_diff(&y2)),
            )
        });
    }

    #[test]
    fn routing_selects_exactly_g_active() {
        check(25, |g| {
            let nt = g.usize_in(1, 64);
            let gg = *g.pick(&[4usize, 8]);
            let ga = g.usize_in(1, gg);
            let mut rng = g.rng().fork();
            let scores = Matrix::randn(nt, gg, 1.0, &mut rng);
            let r = route(&scores, ga);
            for t in 0..nt {
                let cnt = r.mask[t].iter().filter(|&&b| b).count();
                prop_assert(cnt == ga, format!("token {t}: {cnt} != {ga}"))?;
                let gate_sum: f32 = r.gate[t].iter().sum();
                prop_assert(
                    (gate_sum - ga as f32).abs() < 1e-4,
                    format!("gate sum {gate_sum}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn all_blocks_active_with_zero_router_is_plain_ffn() {
        let mut rng = Rng::new(3);
        let (nt, d, dd, g) = (8, 4, 16, 4);
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, dd, 0.3, &mut rng);
        let wo = Matrix::randn(dd, d, 0.3, &mut rng);
        let scores = Matrix::zeros(nt, g);
        let routing = route(&scores, g);
        let y = routed_ffn(&x, &wi, &wo, &routing);
        let want = x.matmul(&wi).relu().matmul(&wo);
        assert!(y.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn backward_with_all_blocks_active_matches_plain_ffn_backward() {
        // Zero router scores + G' = G makes every gate 1.0, so the routed
        // backward must agree with the dense relu-FFN backward assembled
        // from the grad primitives.
        let mut rng = Rng::new(17);
        let (nt, d, dd, g) = (9, 5, 12, 4);
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, dd, 0.4, &mut rng);
        let wo = Matrix::randn(dd, d, 0.4, &mut rng);
        let dy = Matrix::randn(nt, d, 1.0, &mut rng);
        let routing = route(&Matrix::zeros(nt, g), g);
        let (dx, dwi, dwo) = routed_ffn_backward(&x, &wi, &wo, &routing, &dy);
        // Dense reference.
        let h = x.matmul(&wi).relu();
        let dwo_ref = grad::matmul_dw(&h, &dy);
        let dh = grad::matmul_dx(&dy, &wo);
        let dpre = grad::relu_backward(&h, &dh);
        let dwi_ref = grad::matmul_dw(&x, &dpre);
        let dx_ref = grad::matmul_dx(&dpre, &wi);
        assert!(dx.max_abs_diff(&dx_ref) < 1e-4, "{}", dx.max_abs_diff(&dx_ref));
        assert!(dwi.max_abs_diff(&dwi_ref) < 1e-4, "{}", dwi.max_abs_diff(&dwi_ref));
        assert!(dwo.max_abs_diff(&dwo_ref) < 1e-4, "{}", dwo.max_abs_diff(&dwo_ref));
    }

    #[test]
    fn inactive_blocks_get_zero_weight_gradient() {
        let mut rng = Rng::new(18);
        let (nt, d, dd, g, ga) = (6, 4, 8, 4, 1);
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, dd, 0.4, &mut rng);
        let wo = Matrix::randn(dd, d, 0.4, &mut rng);
        let dy = Matrix::randn(nt, d, 1.0, &mut rng);
        let routing = route(&Matrix::randn(nt, g, 1.0, &mut rng), ga);
        let (_, dwi, dwo) = routed_ffn_backward(&x, &wi, &wo, &routing, &dy);
        let dg = dd / g;
        for gi in 0..g {
            let active = (0..nt).any(|t| routing.mask[t][gi]);
            if active {
                continue;
            }
            for r in 0..d {
                assert!(dwi.row(r)[gi * dg..(gi + 1) * dg]
                    .iter()
                    .all(|&v| v == 0.0));
            }
            for r in gi * dg..(gi + 1) * dg {
                assert!(dwo.row(r).iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn flops_ratio_is_beta() {
        let r = routed_flops(512, 2048, 8192, 8, 4) as f64
            / dense_flops(512, 2048, 8192) as f64;
        assert!((r - 0.5).abs() < 1e-9);
    }
}
