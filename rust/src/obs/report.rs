//! `spt obs-report` — aggregate an obs JSONL log into the paper's
//! Fig. 2-style phase breakdown plus sparsity and memory-truth tables,
//! and emit `BENCH_obs_native.json` for the benchdiff gate.
//!
//! The report is a pure fold over the event stream: it reads the log,
//! never the run, so it can be re-rendered offline at any time.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::metrics::Table;
use crate::util::json::{parse, Json};

/// Aggregated view of one obs JSONL run log.
#[derive(Debug, Default)]
pub struct RunSummary {
    /// Command recorded in the header (`train`, `serve`, `generate`).
    pub cmd: String,
    /// Provenance stamp from the header (git SHA, threads, CPU model).
    pub provenance: Json,
    /// Number of `step` events.
    pub steps: u64,
    /// Wall seconds summed over `step` events.
    pub total_step_secs: f64,
    /// Final training loss seen, if any.
    pub last_loss: Option<f64>,
    /// phase -> (calls, secs) summed over all step events.
    pub phases: BTreeMap<String, (u64, f64)>,
    /// Per-layer (density sum, sample count) for mean attention density.
    pub attn_density: Vec<(f64, u64)>,
    /// Per-layer tokens routed to each FFN group, summed over steps.
    pub expert_load: Vec<Vec<u64>>,
    /// Observed workspace high-water (bytes), max over steps.
    pub ws_bytes_peak: u64,
    /// Mean absolute parameter movement per codebook refresh event.
    pub codebook_drift: Vec<f64>,
    /// `(step, loss)` eval points.
    pub evals: Vec<(u64, f64)>,
    /// Memory-truth join: (observed, predicted, model_err), last event.
    pub memory: Option<(u64, u64, f64)>,
    /// The serve daemon's final report event, when present.
    pub serve: Option<Json>,
}

impl RunSummary {
    /// Mean attention density across layers and steps (0 when the run
    /// recorded none — dense modes).
    pub fn attn_density_mean(&self) -> f64 {
        let (sum, n) = self
            .attn_density
            .iter()
            .fold((0.0, 0u64), |(s, n), &(ls, ln)| (s + ls, n + ln));
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Worst per-layer expert imbalance: `max_load / mean_load` over
    /// groups, maxed across layers.  1.0 = perfectly balanced routing.
    pub fn expert_imbalance(&self) -> f64 {
        self.expert_load
            .iter()
            .filter_map(|loads| {
                let total: u64 = loads.iter().sum();
                if total == 0 || loads.is_empty() {
                    return None;
                }
                let mean = total as f64 / loads.len() as f64;
                let max = *loads.iter().max().unwrap() as f64;
                Some(max / mean)
            })
            .fold(0.0, f64::max)
    }

    pub fn steps_per_sec(&self) -> f64 {
        if self.total_step_secs <= 0.0 {
            0.0
        } else {
            self.steps as f64 / self.total_step_secs
        }
    }

    /// Memmodel validation error (`|observed-predicted|/predicted`), or
    /// 0 when the run emitted no memory event.
    pub fn mem_model_err(&self) -> f64 {
        self.memory.map(|(_, _, e)| e).unwrap_or(0.0)
    }
}

fn arr_f64(v: &Json) -> Vec<f64> {
    v.as_arr()
        .map(|a| a.iter().filter_map(Json::as_f64).collect())
        .unwrap_or_default()
}

/// Fold an obs JSONL log into a [`RunSummary`].
pub fn summarize(path: impl AsRef<Path>) -> Result<RunSummary> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading obs log {path:?}"))?;
    let mut s = RunSummary::default();
    let mut saw_header = false;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = parse(line)
            .map_err(|e| anyhow::anyhow!("{path:?} line {}: {e}", i + 1))?;
        match v.get("event").as_str() {
            Some("header") => {
                saw_header = true;
                s.cmd = v.get("cmd").as_str().unwrap_or("").to_string();
                s.provenance = v.get("provenance").clone();
            }
            Some("step") => {
                s.steps += 1;
                s.total_step_secs += v.get("step_s").as_f64().unwrap_or(0.0);
                if let Some(l) = v.get("loss").as_f64() {
                    s.last_loss = Some(l);
                }
                if let Some(m) = v.get("phases").as_obj() {
                    for (phase, pv) in m {
                        let e = s.phases.entry(phase.clone()).or_insert((0, 0.0));
                        e.0 += pv.get("calls").as_f64().unwrap_or(0.0) as u64;
                        e.1 += pv.get("secs").as_f64().unwrap_or(0.0);
                    }
                }
                for (layer, d) in arr_f64(v.get("attn_density")).into_iter().enumerate() {
                    if s.attn_density.len() <= layer {
                        s.attn_density.resize(layer + 1, (0.0, 0));
                    }
                    s.attn_density[layer].0 += d;
                    s.attn_density[layer].1 += 1;
                }
                if let Some(layers) = v.get("expert_load").as_arr() {
                    for (layer, loads) in layers.iter().enumerate() {
                        let loads: Vec<u64> =
                            arr_f64(loads).into_iter().map(|x| x as u64).collect();
                        if s.expert_load.len() <= layer {
                            s.expert_load.resize(layer + 1, Vec::new());
                        }
                        let acc = &mut s.expert_load[layer];
                        if acc.len() < loads.len() {
                            acc.resize(loads.len(), 0);
                        }
                        for (g, n) in loads.into_iter().enumerate() {
                            acc[g] += n;
                        }
                    }
                }
                let ws = v.get("ws_bytes").as_f64().unwrap_or(0.0) as u64;
                s.ws_bytes_peak = s.ws_bytes_peak.max(ws);
            }
            Some("eval") => {
                if let (Some(step), Some(loss)) =
                    (v.get("step").as_f64(), v.get("loss").as_f64())
                {
                    s.evals.push((step as u64, loss));
                }
            }
            Some("refresh") => {
                if let Some(d) = v.get("codebook_drift").as_f64() {
                    s.codebook_drift.push(d);
                }
            }
            Some("memory") => {
                let obs = v.get("observed_bytes").as_f64().unwrap_or(0.0) as u64;
                let pred = v.get("predicted_bytes").as_f64().unwrap_or(0.0) as u64;
                let err = v.get("model_err").as_f64().unwrap_or(0.0);
                s.memory = Some((obs, pred, err));
            }
            Some("serve_report") => s.serve = Some(v),
            _ => {}
        }
    }
    if !saw_header {
        bail!("{path:?}: not an obs log (no header event)");
    }
    Ok(s)
}

/// Render the summary as markdown tables (phase breakdown, attention
/// density, expert load, memory truth) via [`metrics::Table`].
/// Sections the run never recorded are skipped, so serve-only and
/// dense-mode logs render cleanly.
pub fn render(s: &RunSummary) -> String {
    let mut out = String::new();
    let prov = &s.provenance;
    out.push_str(&format!(
        "obs-report: cmd={} steps={} git_sha={} threads={} cpu={}\n",
        if s.cmd.is_empty() { "?" } else { &s.cmd },
        s.steps,
        prov.get("git_sha").as_str().unwrap_or("unknown"),
        prov.get("rayon_threads").as_usize().unwrap_or(0),
        prov.get("cpu_model").as_str().unwrap_or("unknown"),
    ));

    if !s.phases.is_empty() {
        let total = s.phases.values().map(|&(_, secs)| secs).sum::<f64>().max(1e-12);
        let mut t = Table::new(
            "Phase breakdown (probe forward + step boundaries)",
            &["phase", "calls", "secs", "share"],
        );
        for (phase, &(calls, secs)) in &s.phases {
            t.row(&[
                phase.clone(),
                calls.to_string(),
                format!("{secs:.4}"),
                format!("{:.1}%", 100.0 * secs / total),
            ]);
        }
        out.push('\n');
        out.push_str(&t.render());
    }

    if !s.attn_density.is_empty() {
        let mut t = Table::new(
            "Attention density (mean top-L nnz ratio)",
            &["layer", "density"],
        );
        for (layer, &(sum, n)) in s.attn_density.iter().enumerate() {
            let mean = if n == 0 { 0.0 } else { sum / n as f64 };
            t.row(&[layer.to_string(), format!("{mean:.4}")]);
        }
        out.push('\n');
        out.push_str(&t.render());
    }

    if !s.expert_load.is_empty() {
        let mut t = Table::new(
            "Routed-FFN expert load (tokens per group)",
            &["layer", "load per group", "imbalance"],
        );
        for (layer, loads) in s.expert_load.iter().enumerate() {
            let total: u64 = loads.iter().sum();
            let imb = if total == 0 || loads.is_empty() {
                0.0
            } else {
                *loads.iter().max().unwrap() as f64
                    / (total as f64 / loads.len() as f64)
            };
            let joined =
                loads.iter().map(u64::to_string).collect::<Vec<_>>().join(" ");
            t.row(&[layer.to_string(), joined, format!("{imb:.2}")]);
        }
        out.push('\n');
        out.push_str(&t.render());
    }

    if s.memory.is_some() || s.ws_bytes_peak > 0 {
        let mut t = Table::new(
            "Memory truth (observed vs memmodel)",
            &["channel", "observed", "predicted", "model err"],
        );
        if let Some((obs, pred, err)) = s.memory {
            t.row(&[
                "peak".to_string(),
                crate::util::fmt_bytes(obs),
                crate::util::fmt_bytes(pred),
                format!("{:.1}%", 100.0 * err),
            ]);
        }
        if s.ws_bytes_peak > 0 {
            t.row(&[
                "gemm workspace".to_string(),
                crate::util::fmt_bytes(s.ws_bytes_peak),
                "-".to_string(),
                "-".to_string(),
            ]);
        }
        out.push('\n');
        out.push_str(&t.render());
    }

    if !s.codebook_drift.is_empty() || !s.evals.is_empty() {
        let mut t = Table::new("Training signals", &["signal", "value"]);
        if let Some(loss) = s.last_loss {
            t.row(&["final step loss".to_string(), format!("{loss:.6}")]);
        }
        for &(step, loss) in &s.evals {
            t.row(&[format!("eval@{step}"), format!("{loss:.6}")]);
        }
        for (i, d) in s.codebook_drift.iter().enumerate() {
            t.row(&[format!("codebook drift #{i}"), format!("{d:.6}")]);
        }
        out.push('\n');
        out.push_str(&t.render());
    }

    if let Some(serve) = &s.serve {
        let mut t = Table::new("Serve report", &["field", "value"]);
        for key in [
            "completions",
            "decode_steps",
            "prefill_tokens",
            "shared_prefill_tokens",
            "prefix_hit_rate",
            "peak_pages_in_use",
            "pool_pages",
        ] {
            let v = serve.get(key);
            if !matches!(v, Json::Null) {
                t.row(&[key.to_string(), v.to_string()]);
            }
        }
        out.push('\n');
        out.push_str(&t.render());
    }

    if s.steps > 0 {
        out.push_str(&format!(
            "\nthroughput: {:.2} steps/s over {} steps ({:.3} s)\n",
            s.steps_per_sec(),
            s.steps,
            s.total_step_secs
        ));
    }
    out
}

/// The `BENCH_obs_native.json` payload consumed by `cargo xtask
/// benchdiff` (lower is better for density, imbalance, and model error;
/// higher for throughput).
pub fn bench_json(s: &RunSummary) -> Json {
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("obs_native".to_string()));
    top.insert("steps_per_sec".to_string(), Json::Num(s.steps_per_sec()));
    top.insert("attn_density_mean".to_string(), Json::Num(s.attn_density_mean()));
    top.insert("expert_imbalance".to_string(), Json::Num(s.expert_imbalance()));
    top.insert("mem_model_err".to_string(), Json::Num(s.mem_model_err()));
    let prov = if matches!(s.provenance, Json::Obj(_)) {
        s.provenance.clone()
    } else {
        crate::util::provenance::provenance()
    };
    top.insert("provenance".to_string(), prov);
    Json::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ObsLog;

    fn fixture_log(dir: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.jsonl");
        let mut log = ObsLog::create(&path, "train").unwrap();
        for step in 0..2u64 {
            let mut phases = BTreeMap::new();
            for (name, secs) in [("mha", 0.2), ("ffn", 0.6), ("ln", 0.1), ("optimizer", 0.1)]
            {
                let mut p = BTreeMap::new();
                p.insert("calls".to_string(), Json::Num(1.0));
                p.insert("secs".to_string(), Json::Num(secs));
                phases.insert(name.to_string(), Json::Obj(p));
            }
            log.event(
                "step",
                vec![
                    ("step", Json::Num(step as f64)),
                    ("loss", Json::Num(3.0 - step as f64)),
                    ("step_s", Json::Num(1.0)),
                    ("phases", Json::Obj(phases)),
                    (
                        "attn_density",
                        Json::Arr(vec![Json::Num(0.125), Json::Num(0.25)]),
                    ),
                    (
                        "expert_load",
                        Json::Arr(vec![Json::Arr(vec![
                            Json::Num(30.0),
                            Json::Num(10.0),
                        ])]),
                    ),
                    ("ws_bytes", Json::Num(4096.0)),
                ],
            )
            .unwrap();
        }
        log.event(
            "memory",
            vec![
                ("observed_bytes", Json::Num(900.0)),
                ("predicted_bytes", Json::Num(1000.0)),
                ("model_err", Json::Num(0.1)),
            ],
        )
        .unwrap();
        log.flush().unwrap();
        path
    }

    #[test]
    fn summarize_folds_the_event_stream() {
        let path = fixture_log("spt_obs_report_sum_test");
        let s = summarize(&path).unwrap();
        assert_eq!(s.cmd, "train");
        assert_eq!(s.steps, 2);
        assert_eq!(s.last_loss, Some(2.0));
        assert_eq!(s.phases.len(), 4);
        assert_eq!(s.phases["ffn"], (2, 1.2));
        // Per-layer density means: layer 0 = 0.125, layer 1 = 0.25.
        assert!((s.attn_density_mean() - 0.1875).abs() < 1e-12);
        // One layer, loads [60, 20]: imbalance = 60 / 40 = 1.5.
        assert_eq!(s.expert_load, vec![vec![60, 20]]);
        assert!((s.expert_imbalance() - 1.5).abs() < 1e-12);
        assert_eq!(s.memory, Some((900, 1000, 0.1)));
        assert!((s.mem_model_err() - 0.1).abs() < 1e-12);
        assert!((s.steps_per_sec() - 1.0).abs() < 1e-12);
        assert_eq!(s.ws_bytes_peak, 4096);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn render_emits_all_sections() {
        let path = fixture_log("spt_obs_report_render_test");
        let s = summarize(&path).unwrap();
        let text = render(&s);
        assert!(text.contains("Phase breakdown"));
        assert!(text.contains("| mha"));
        assert!(text.contains("| optimizer"));
        assert!(text.contains("Attention density"));
        assert!(text.contains("Routed-FFN expert load"));
        assert!(text.contains("30 10") || text.contains("60 20"));
        assert!(text.contains("Memory truth"));
        assert!(text.contains("10.0%"), "model err rendered: {text}");
        assert!(text.contains("throughput"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bench_json_has_gate_metrics() {
        let path = fixture_log("spt_obs_report_bench_test");
        let s = summarize(&path).unwrap();
        let j = bench_json(&s);
        assert_eq!(j.get("bench").as_str(), Some("obs_native"));
        assert!((j.get("steps_per_sec").as_f64().unwrap() - 1.0).abs() < 1e-12);
        assert!(j.get("attn_density_mean").as_f64().is_some());
        assert!(j.get("expert_imbalance").as_f64().is_some());
        assert_eq!(j.get("mem_model_err"), &Json::Num(0.1));
        assert!(j.get("provenance").get("git_sha").as_str().is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn summarize_rejects_non_obs_files() {
        let dir = std::env::temp_dir().join("spt_obs_report_reject_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.jsonl");
        std::fs::write(&path, "{\"event\":\"step\"}\n").unwrap();
        assert!(summarize(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
