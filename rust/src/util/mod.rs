//! Shared substrates: JSON, PRNG, property-testing harness, small helpers.
//!
//! The offline crate registry for this build ships only the `xla` crate and
//! its dependencies, so the usual ecosystem crates (serde, rand, proptest,
//! clap, criterion) are reimplemented here at the scale this project needs.

pub mod crc;
pub mod fault;
pub mod json;
pub mod lock;
pub mod log;
pub mod proptest;
pub mod provenance;
pub mod retry;
pub mod rng;

/// Human-readable byte count (Table/figure reports).
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b >= K * K * K {
        format!("{:.2} GB", b / (K * K * K))
    } else if b >= K * K {
        format!("{:.0} MB", b / (K * K))
    } else if b >= K {
        format!("{:.0} KB", b / K)
    } else {
        format!("{b:.0} B")
    }
}

/// Human-readable duration.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.1} h", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.1} min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2} s")
    } else {
        format!("{:.1} ms", secs * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2 KB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3 MB");
        assert_eq!(fmt_bytes(5_368_709_120), "5.00 GB");
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(0.0531), "53.1 ms");
        assert_eq!(fmt_duration(2.5), "2.50 s");
        assert_eq!(fmt_duration(90.0), "1.5 min");
        assert_eq!(fmt_duration(6.7 * 3600.0), "6.7 h");
    }
}
