"""L2: training step — loss, gradients, AdamW — lowered to a single HLO.

The whole fine-tuning step (forward, backward through the custom-VJP Pallas
kernels, masked AdamW update with weight decay) is one jitted function so
XLA fuses it into one executable; the rust coordinator calls it with
(params, opt_state, tokens, targets) and receives (loss, params', opt').

Frozen leaves (per ``model.trainable_mask``) keep zero-sized optimizer
moments is not expressible in a static pytree, so moments exist for every
leaf but masked leaves are never updated — the masking multiplies the
update by 0/1, which XLA constant-folds into no-ops for frozen tensors.
Weight decay is enabled (paper §6.1: "weight decay is enabled for the
optimizer") and applied only to trainable 2-D matrices.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import model as M


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 1e-4
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def init_opt_state(params: Any) -> dict[str, Any]:
    """AdamW state: first/second moments per leaf + shared step counter."""
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params: Any,
    grads: Any,
    opt: dict[str, Any],
    mask: Any,
    oc: OptConfig,
) -> tuple[Any, dict[str, Any]]:
    """Masked AdamW with global-norm clipping."""
    # Global-norm clip over trainable grads only.
    sq = jax.tree_util.tree_map(
        lambda g, t: jnp.sum(g * g) if t else jnp.zeros(()), grads, mask
    )
    gnorm = jnp.sqrt(
        sum(jax.tree_util.tree_leaves(sq)) + 1e-12
    )
    scale = jnp.minimum(1.0, oc.grad_clip / gnorm)
    step = opt["step"] + 1
    b1c = 1.0 - oc.beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - oc.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, t):
        if not t:
            return p, m, v
        g = g * scale
        m2 = oc.beta1 * m + (1.0 - oc.beta1) * g
        v2 = oc.beta2 * v + (1.0 - oc.beta2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + oc.eps)
        if p.ndim >= 2:  # decay matrices, not vectors/scalars
            delta = delta + oc.weight_decay * p
        return p - oc.lr * delta, m2, v2

    out = jax.tree_util.tree_map(
        upd, params, grads, opt["m"], opt["v"], mask,
        is_leaf=lambda x: isinstance(x, jax.Array),
    )
    new_p = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}


def make_train_step(mc: M.ModelConfig, mode: str, oc: OptConfig | None = None):
    """Build the jittable end-to-end fine-tuning step for a model config."""
    oc = oc or OptConfig()

    def step(params, opt, tokens, targets):
        mask = M.trainable_mask(params, mode)
        loss, grads = jax.value_and_grad(M.lm_loss)(
            params, tokens, targets, mc, mode
        )
        # Zero grads of frozen leaves (stop-grad already keeps most at 0,
        # but e.g. base W receives real grads in lora mode — mask them).
        grads = jax.tree_util.tree_map(
            lambda g, t: g if t else jnp.zeros_like(g), grads, mask
        )
        new_params, new_opt = adamw_update(params, grads, opt, mask, oc)
        return loss, new_params, new_opt

    return step


def make_train_chunk(
    mc: M.ModelConfig, mode: str, k: int, oc: OptConfig | None = None
):
    """K microbatches per dispatch via lax.scan — the coordinator's fast
    path: host<->device marshalling of params/optimizer state is amortized
    over k steps (see EXPERIMENTS.md §Perf)."""
    oc = oc or OptConfig()
    step = make_train_step(mc, mode, oc)

    def chunk(params, opt, tokens_k, targets_k):
        def body(carry, batch):
            p, o = carry
            tok, tgt = batch
            loss, p, o = step(p, o, tok, tgt)
            return (p, o), loss

        (params, opt), losses = jax.lax.scan(
            body, (params, opt), (tokens_k, targets_k)
        )
        return losses, params, opt

    return chunk


def make_qa_logits(mc: M.ModelConfig, mode: str, answer_pos: int,
                   choice_tokens: tuple[int, ...] = (3, 4, 5, 6)):
    """Choice-token logits at the answer slot — the MMLU-surrogate scorer
    (Table 3).  answer_pos is static: the taskgen renders the answer slot
    at a fixed position."""

    def qa(params, tokens):
        logits, _ = M.model_forward(params, tokens, mc, mode)
        at_slot = logits[:, answer_pos, :]  # [B, V]
        return at_slot[:, jnp.array(choice_tokens)]

    return qa


def make_eval_loss(mc: M.ModelConfig, mode: str):
    """Eval loss (no update) — PPL = exp(loss); paper's Wikitext metric."""

    def ev(params, tokens, targets):
        return M.lm_loss(params, tokens, targets, mc, mode, lb_weight=0.0)

    return ev


def make_block_fwdbwd(cfg: M.BlockConfig, mode: str, lr: float = 1e-3):
    """Block-level fwd+bwd+SGD for the profiling benches (paper Fig. 8:
    'time to compute the forward and backward passes for a Transformer
    block').  Loss is a simple energy so the bwd exercises every kernel."""

    def step(params, x):
        mask = M.trainable_mask(params, mode)

        def loss_fn(p):
            y, lb = M.block_forward(p, x, cfg, mode)
            return jnp.mean(y * y) + 0.01 * lb

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params = jax.tree_util.tree_map(
            lambda p, g, t: p - lr * g if t else p, params, grads, mask
        )
        return loss, new_params

    return step


def make_mha_fwdbwd(cfg: M.BlockConfig, mode: str):
    """MHA-module-only fwd+bwd (paper Table 1/4 decomposition)."""

    def step(params, x):
        def loss_fn(p):
            y = M.mha(p, x, cfg, mode)
            return jnp.mean(y * y)

        return jax.value_and_grad(loss_fn)(params)

    return step


def make_ffn_fwdbwd(cfg: M.BlockConfig, mode: str):
    """FFN-module-only fwd+bwd (paper Table 1/4 decomposition)."""

    def step(params, x):
        def loss_fn(p):
            y, scores = M.ffn(p, x, cfg, mode)
            loss = jnp.mean(y * y)
            if scores is not None:
                from .kernels import routed_ffn as R

                loss = loss + 0.01 * R.load_balance_loss(scores, cfg.ffn_active)
            return loss

        return jax.value_and_grad(loss_fn)(params)

    return step


def make_codebook_refresh(cfg: M.BlockConfig):
    """DKM codebook refresh over a batch of per-head Q/K vectors
    (paper §5.1: run every ~20 mini-batches, off the hot step)."""
    from .kernels import pq

    def refresh(codebooks, vecs):
        return pq.pq_codebook_update(vecs, codebooks, lr=0.5)

    return refresh


def make_model_codebook_refresh(mc: M.ModelConfig, lr: float = 0.5):
    """Whole-model DKM refresh (spt mode): run a forward pass, and at each
    layer update that layer's Q/K codebooks against the current per-head
    projections (paper §5.1: 'codebooks represent centroids of the query
    and key vectors, which change slowly').

    Inputs: (params, tokens) -> (new_pq_q, new_pq_k) stacked per layer.
    The coordinator patches these leaves back into its device state.
    """
    from .kernels import pq

    cfg = mc.block

    def refresh(params, tokens):
        b, n = tokens.shape
        x = params["embed"][tokens] + params["pos"][:n][None]
        h, dh = cfg.n_heads, cfg.d_head

        def split(t):
            return (
                t.reshape(b, n, h, dh)
                .transpose(0, 2, 1, 3)
                .reshape(b * h, n, dh)
            )

        def body(x_c, layer_p):
            xn = M.layer_norm(x_c, layer_p["ln1_scale"], layer_p["ln1_bias"])
            q = split(M._proj(layer_p, "q", xn, "spt"))
            k = split(M._proj(layer_p, "k", xn, "spt"))
            new_q = pq.pq_codebook_update(q, layer_p["pq_q"], lr=lr)
            new_k = pq.pq_codebook_update(k, layer_p["pq_k"], lr=lr)
            x_next, _ = M.block_forward(layer_p, x_c, cfg, "spt", causal=True)
            return x_next, (new_q, new_k)

        _, (pq_q, pq_k) = jax.lax.scan(body, x, params["blocks"])
        return pq_q, pq_k

    return refresh
