//! Loaded-checkpoint inference: [`InferModel`] (weights materialized
//! once, packed-B panels cached for the whole session) and [`Session`]
//! (prefill + incremental decode for one sequence).
//!
//! ## The determinism / parity contract
//!
//! `prefill(prompt)` followed by N teacher-forced decode steps produces
//! logits **bit-identical** to one training forward over the
//! `prompt + N`-token sequence, in every mode and at any rayon pool
//! size.  The pieces:
//!
//! * Prefill *is* the training forward
//!   ([`NativeBackend::forward_model`]) over the prompt; the per-layer
//!   per-head K/V projections in its trace seed the [`DecodeCache`].
//! * Every decode-step op is row-local and runs in the training
//!   kernel's per-row operation order (projections through the packed
//!   GEMM, layer norm, the cached-attention row kernels, the routed
//!   FFN's per-token gather), so row `pos` of the incremental path
//!   carries the training forward's exact bits by induction over
//!   positions — causality means the full forward's row `pos` never
//!   reads rows past `pos`.
//! * **Sparse L pinning:** the training forward derives attention's L
//!   from the *full* sequence length.  A session therefore fixes
//!   `l_sess = topl(target_len)` at construction — prefill runs with
//!   `min(l_sess, prompt_len)` and every decode step selects
//!   `min(l_sess, pos+1)` keys — which reproduces the full-sequence
//!   selection exactly (future keys only ever occupy the sentinel
//!   bucket and zero-probability padding slots; see
//!   [`crate::sparse::mha::decode_attend_row`]).
//!
//! ## The paged path
//!
//! The serve driver's sequences keep their caches in a shared
//! [`PagePool`] instead of per-slot dense matrices.  [`decode_runs`]
//! generalizes the batched step to a *run* of consecutive tokens per
//! sequence (chunked prefill is just a multi-token run): each run's
//! K/V rows are appended for the whole chunk first, then every row
//! attends at its own absolute position `p` against a contiguous
//! gather of cached rows `0..=p` — bit-identical to the dense cache
//! layout the kernels were proven on, so paging/gathering changes
//! *where* bytes live, never their values.  Row `p`'s output is a pure
//! function of `(tokens[0..=p], l_sess)`: position 0 through the
//! decode row kernel equals forward row 0 exactly (softmax over one
//! element is 1.0), and induction over positions does the rest — which
//! is also why prefix pages can be shared across requests keyed only
//! on `(l_sess, token prefix)`.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use rayon::prelude::*;

use crate::config::{Mode, RunConfig};
use crate::coordinator::checkpoint;
use crate::coordinator::native::{ItemTrace, Layout, NativeBackend, Weights};
use crate::coordinator::TrainState;
use crate::infer::cache::{DecodeCache, LayerCache, PagePool, PageTable};
use crate::sparse::bspmv::{self, Routing};
use crate::sparse::{attention, grad, mha, pq};
use crate::sparse::{Codes, Matrix, Workspace};

/// A checkpoint materialized for inference: the trainer's own layout and
/// effective-weight materialization (LoRA deltas folded in, PQ codebooks
/// split per head, packed-B panels for the six projections built once
/// and reused by every prefill and decode step of every session).
pub struct InferModel {
    pub(crate) backend: NativeBackend,
    pub(crate) layout: Arc<Layout>,
    pub(crate) weights: Weights,
    pub(crate) state: TrainState,
    pub(crate) model: String,
    pub(crate) mode: Mode,
}

impl InferModel {
    /// Materialize from an in-memory training state.
    pub fn new(rc: &RunConfig, state: TrainState) -> Result<Self> {
        let backend = NativeBackend::new();
        let layout = backend.layout(rc)?;
        let weights = Weights::materialize(&layout, &state)
            .with_context(|| format!("materializing '{}' ({})", rc.model, rc.mode.as_str()))?;
        Ok(InferModel {
            backend,
            layout,
            weights,
            state,
            model: rc.model.clone(),
            mode: rc.mode,
        })
    }

    /// Load a checkpoint from disk, verifying its embedded identity
    /// (v2 headers) against the requested model/mode before touching a
    /// single leaf.  Legacy v1 checkpoints carry no identity; shape
    /// mismatches then surface from materialization.
    pub fn from_checkpoint(rc: &RunConfig, path: impl AsRef<Path>) -> Result<Self> {
        let (state, meta) = checkpoint::load_tagged(path.as_ref())?;
        if let Some(meta) = &meta {
            meta.verify(&rc.model, rc.mode)?;
        }
        let model = Self::new(rc, state)?;
        if let Some(meta) = &meta {
            meta.verify_layers(&model.model, model.mode, model.layout.layers.len())?;
        }
        Ok(model)
    }

    pub fn vocab(&self) -> usize {
        self.layout.vocab
    }

    pub fn max_seq(&self) -> usize {
        self.layout.max_seq
    }

    pub fn n_layers(&self) -> usize {
        self.layout.layers.len()
    }

    pub fn model_name(&self) -> &str {
        &self.model
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }
}

/// Where a sequence's cached K/V (and codes) live: a private dense
/// cache (the solo [`Session`] reference) or a page table into a
/// driver-owned [`PagePool`].
pub(crate) enum KvCache {
    Dense(DecodeCache),
    Paged(PageTable),
}

/// One sequence's incremental decode state: the cache, the absolute
/// position (tokens consumed so far), the session-pinned sparse L, and
/// the target length that L was pinned to (decoding past it would
/// silently void the parity contract, so [`decode_runs`] refuses).
pub(crate) struct DecodeState {
    pub(crate) cache: KvCache,
    pub(crate) pos: usize,
    pub(crate) l_sess: usize,
    pub(crate) target_len: usize,
}

/// Per-worker scratch for the (row × head) attention fan-out.  The
/// gather buffers hold a paged sequence's cached rows contiguously for
/// the row kernels (contents never affect results — they are fully
/// overwritten per row).
struct RowScratch {
    sparse: mha::DecodeScratch,
    dense_logits: Vec<f32>,
    gk: Matrix,
    gv: Matrix,
    gcodes: Codes,
}

impl Default for RowScratch {
    fn default() -> Self {
        RowScratch {
            sparse: mha::DecodeScratch::default(),
            dense_logits: Vec::new(),
            gk: Matrix::zeros(0, 0),
            gv: Matrix::zeros(0, 0),
            gcodes: Codes::zeros(0, 0),
        }
    }
}

/// Cross-step scratch for [`decode_batch`]: the GEMM workspace and the
/// router's [`Routing`] buffers, reused across every step of a session
/// or serve loop (this is what makes `bspmv::route_into`'s buffer reuse
/// span the whole serving run, not just one step's layers).  Contents
/// never affect results.
pub(crate) struct StepScratch {
    ws: Workspace,
    routing: Routing,
}

impl Default for StepScratch {
    fn default() -> Self {
        StepScratch {
            ws: Workspace::default(),
            routing: Routing { mask: Vec::new(), gate: Vec::new(), g: 1, g_active: 1 },
        }
    }
}

/// Run the training forward over `prompt`, seed a decode cache from its
/// trace, and return the state plus the last row's logits.
pub(crate) fn prefill_state(
    model: &InferModel,
    prompt: &[i32],
    target_len: usize,
) -> Result<(DecodeState, Vec<f32>)> {
    let layout = &*model.layout;
    if prompt.is_empty() {
        bail!("prompt must contain at least one token");
    }
    if target_len < prompt.len() {
        bail!(
            "target length {target_len} shorter than the {}-token prompt",
            prompt.len()
        );
    }
    if target_len > layout.max_seq {
        bail!(
            "target length {target_len} exceeds max_seq {} of '{}'",
            layout.max_seq,
            model.model
        );
    }
    // The session's L is the *target* sequence length's L; prefill clamps
    // it to the prompt (selection needs l <= keys), which preserves every
    // bit of the full-length forward (see the module docs).
    let l_sess = layout.sparsity.topl(target_len).min(target_len);
    let sparse = model.backend.sparse_layers_with_l(
        layout,
        &model.weights,
        l_sess.min(prompt.len()),
    )?;
    let mut ws = Workspace::default();
    let trace = model.backend.forward_model(
        layout,
        &model.weights,
        &model.state,
        prompt,
        sparse.as_deref(),
        &mut ws,
    )?;
    let ItemTrace { layers, xf, .. } = trace;
    let mut cache_layers = Vec::with_capacity(layers.len());
    for (li, lt) in layers.into_iter().enumerate() {
        let codes = model.weights.layers[li].codebooks.as_ref().map(|cbs| {
            lt.k.iter()
                .zip(cbs)
                .map(|(kh, cb)| pq::quantize(&kh.data, cb))
                .collect::<Vec<_>>()
        });
        cache_layers.push(LayerCache { k: lt.k, v: lt.v, codes });
    }
    let cache = DecodeCache { layers: cache_layers };
    // Last-row logits through the tied readout, on the same NT kernel as
    // the decode path and the training readout (`grad::matmul_dx` is
    // row-local, so the 1-row product equals that row of the full
    // readout by construction — no hand-rolled twin to keep in sync).
    let mut last = Matrix::zeros(1, xf.cols);
    last.row_mut(0).copy_from_slice(xf.row(prompt.len() - 1));
    let logits = grad::matmul_dx(&last, &model.weights.tok).data;
    Ok((
        DecodeState {
            cache: KvCache::Dense(cache),
            pos: prompt.len(),
            l_sess,
            target_len,
        },
        logits,
    ))
}

/// One decode step for a batch of independent sequences, one token
/// each: the single-token special case of [`decode_runs`] (no pool, so
/// every state must hold a dense cache — the solo [`Session`] path).
pub(crate) fn decode_batch(
    model: &InferModel,
    states: &mut [DecodeState],
    tokens: &[i32],
    scratch: &mut StepScratch,
) -> Result<Matrix> {
    assert_eq!(tokens.len(), states.len(), "one token per in-flight sequence");
    let runs: Vec<Vec<i32>> = tokens.iter().map(|&t| vec![t]).collect();
    decode_runs(model, states, &runs, scratch, None)
}

/// One step over a batch of independent sequences, a *run* of
/// consecutive tokens per sequence: embed every token at its sequence's
/// absolute position, run the layer stack with one GEMM per projection
/// and one routed-FFN call per layer across all in-flight rows, append
/// each run's K/V (and key codes), attend per (row × head) against each
/// sequence's cache, and return the `[total_rows, vocab]` logits with
/// each sequence's rows grouped contiguously in batch order.
///
/// Multi-token runs are chunked prefill: the whole chunk's K/V rows are
/// appended per layer *before* attention, and each row then attends at
/// its own position `p` over cached rows `0..=p` — exactly the causal
/// selection the training forward makes (see the module docs for the
/// induction).  Every op is row-local in the training kernels' per-row
/// operation order, so each sequence's rows are bit-identical to a solo
/// prefill+decode — batching, chunking, paging, and the rayon fan-out
/// never change results.
///
/// Multi-token runs require a paged cache (the dense append path holds
/// the `rows == pos+1` kernel contract only for single-token steps),
/// and paged states require `pool`.  Page tables must already map every
/// position the runs will write — the serve driver's admission
/// accounting reserves capacity up front, so allocation never happens
/// mid-step.
pub(crate) fn decode_runs(
    model: &InferModel,
    states: &mut [DecodeState],
    runs: &[Vec<i32>],
    scratch: &mut StepScratch,
    mut pool: Option<&mut PagePool>,
) -> Result<Matrix> {
    let layout = &*model.layout;
    let s_count = states.len();
    assert_eq!(runs.len(), s_count, "one run per in-flight sequence");
    assert!(s_count > 0, "empty decode batch");
    let (heads, dh, d) = (layout.heads, layout.d_head, layout.d);
    // Map flat row index -> (sequence, offset within its run), and
    // validate: runs are non-empty, stay within each sequence's pinned
    // target length (L was derived from that total, so further steps
    // would silently match no full-sequence forward), and match the
    // cache kind.
    let mut row_seq = Vec::new();
    for (si, run) in runs.iter().enumerate() {
        let st = &states[si];
        if run.is_empty() {
            bail!("empty token run for sequence {si}");
        }
        if st.pos + run.len() > st.target_len {
            bail!(
                "sequence already holds its target length {} (L was pinned \
                 to it); start a new session with a longer target",
                st.target_len
            );
        }
        match &st.cache {
            KvCache::Dense(_) if run.len() > 1 => {
                bail!("multi-token runs need a paged cache (dense appends are per-step)")
            }
            KvCache::Paged(_) if pool.is_none() => {
                bail!("paged sequence {si} decoded without its page pool")
            }
            _ => {}
        }
        for j in 0..run.len() {
            row_seq.push((si, j));
        }
    }
    let total = row_seq.len();
    // Embed each token at its own absolute position.
    let mut x = Matrix::zeros(total, d);
    for (r, &(si, j)) in row_seq.iter().enumerate() {
        let st = &states[si];
        let row = model.backend.embed_at(
            layout,
            &model.state,
            &runs[si][j..j + 1],
            st.pos + j,
        )?;
        x.row_mut(r).copy_from_slice(row.row(0));
    }
    let StepScratch { ws, routing } = scratch;
    for (li, lw) in model.weights.layers.iter().enumerate() {
        let a_in = grad::layer_norm(&x, &lw.ln1_scale, &lw.ln1_bias);
        let q = a_in.matmul_packed(&lw.wq_p);
        let k = a_in.matmul_packed(&lw.wk_p);
        let v = a_in.matmul_packed(&lw.wv_p);
        // Append every new K/V row (and key codes) before attending:
        // each row attends to itself, and later rows of a run see the
        // earlier ones (each row's own position bound keeps causality).
        for (r, &(si, j)) in row_seq.iter().enumerate() {
            let st = &mut states[si];
            match &mut st.cache {
                KvCache::Dense(cache) => {
                    cache.append(li, k.row(r), v.row(r), lw.codebooks.as_deref())?;
                }
                KvCache::Paged(table) => {
                    let pool = pool.as_deref_mut().expect("validated above");
                    pool.write_row(
                        table,
                        st.pos + j,
                        li,
                        k.row(r),
                        v.row(r),
                        lw.codebooks.as_deref(),
                    )?;
                }
            }
        }
        // Cached attention, parallel over (row × head) into disjoint
        // `dh`-wide slices of the concatenated output.  Paged rows
        // first gather their cached prefix into contiguous per-worker
        // scratch, so both arms run the same proven row kernels.
        let mut attn_out = Matrix::zeros(total, d);
        let states_ro: &[DecodeState] = states;
        let q_ref = &q;
        let row_seq_ref = &row_seq;
        let pool_ro = pool.as_deref();
        attn_out
            .data
            .par_chunks_mut(dh)
            .enumerate()
            .for_each_init(RowScratch::default, |scratch, (ci, out)| {
                let (row, h) = (ci / heads, ci % heads);
                let (si, j) = row_seq_ref[row];
                let st = &states_ro[si];
                let p = st.pos + j;
                let q_row = &q_ref.row(row)[h * dh..(h + 1) * dh];
                match &st.cache {
                    KvCache::Dense(cache) => {
                        let lc = &cache.layers[li];
                        match (&lc.codes, &lw.codebooks) {
                            (Some(codes), Some(cbs)) => mha::decode_attend_row(
                                &cbs[h],
                                q_row,
                                &lc.k[h],
                                &lc.v[h],
                                &codes[h],
                                p,
                                st.l_sess,
                                out,
                                &mut scratch.sparse,
                            ),
                            _ => attention::dense_attend_row(
                                q_row,
                                &lc.k[h],
                                &lc.v[h],
                                &mut scratch.dense_logits,
                                out,
                            ),
                        }
                    }
                    KvCache::Paged(table) => {
                        let pool = pool_ro.expect("validated above");
                        match &lw.codebooks {
                            Some(cbs) => {
                                pool.gather(
                                    table,
                                    li,
                                    h,
                                    p + 1,
                                    &mut scratch.gk,
                                    &mut scratch.gv,
                                    Some(&mut scratch.gcodes),
                                );
                                mha::decode_attend_row(
                                    &cbs[h],
                                    q_row,
                                    &scratch.gk,
                                    &scratch.gv,
                                    &scratch.gcodes,
                                    p,
                                    st.l_sess,
                                    out,
                                    &mut scratch.sparse,
                                )
                            }
                            None => {
                                pool.gather(
                                    table,
                                    li,
                                    h,
                                    p + 1,
                                    &mut scratch.gk,
                                    &mut scratch.gv,
                                    None,
                                );
                                attention::dense_attend_row(
                                    q_row,
                                    &scratch.gk,
                                    &scratch.gv,
                                    &mut scratch.dense_logits,
                                    out,
                                )
                            }
                        }
                    }
                }
            });
        let x_mid = x.add(&attn_out.matmul_packed(&lw.wo_p));
        let f_in = grad::layer_norm(&x_mid, &lw.ln2_scale, &lw.ln2_bias);
        let f = if layout.mode == Mode::Spt {
            let router = lw.router.as_ref().context("spt mode without router")?;
            let scores = f_in.matmul_ws(router, ws);
            let g_active = layout.sparsity.active_groups(layout.groups).min(layout.groups);
            bspmv::route_into(&scores, g_active, routing);
            mha::routed_ffn_auto(&f_in, &lw.wi, &lw.wo2, routing)
        } else {
            let wi_p = lw.wi_p.as_ref().context("dense mode without packed W_I")?;
            let wo2_p = lw.wo2_p.as_ref().context("dense mode without packed W_O")?;
            let h1 = f_in.matmul_packed(wi_p).relu();
            h1.matmul_packed(wo2_p)
        };
        x = x_mid.add(&f);
    }
    let xf = grad::layer_norm(&x, &model.weights.lnf_scale, &model.weights.lnf_bias);
    for (si, st) in states.iter_mut().enumerate() {
        st.pos += runs[si].len();
    }
    // Tied readout for every in-flight row (NT kernel, row-local).
    Ok(grad::matmul_dx_ws(&xf, &model.weights.tok, ws))
}

/// One generation stream over an [`InferModel`].
pub struct Session<'m> {
    model: &'m InferModel,
    state: DecodeState,
    last_logits: Vec<f32>,
    scratch: StepScratch,
}

impl<'m> Session<'m> {
    /// Prefill `prompt` with the sparse L pinned to `target_len` (the
    /// prompt length plus every token you intend to decode; the parity
    /// contract is stated against this total, and decoding past it is
    /// refused).
    pub fn new(model: &'m InferModel, prompt: &[i32], target_len: usize) -> Result<Self> {
        let (state, last_logits) = prefill_state(model, prompt, target_len)?;
        Ok(Session {
            model,
            state,
            last_logits,
            scratch: StepScratch::default(),
        })
    }

    /// Logits of the most recently consumed position (`[vocab]`).
    pub fn logits(&self) -> &[f32] {
        &self.last_logits
    }

    /// Tokens consumed so far (prompt + decoded).
    pub fn pos(&self) -> usize {
        self.state.pos
    }

    /// Measured decode-cache footprint in bytes.  A solo session always
    /// owns a private dense cache (paged storage is accounted by the
    /// serve driver's pool, not per sequence).
    pub fn cache_bytes(&self) -> usize {
        match &self.state.cache {
            KvCache::Dense(cache) => cache.bytes(),
            KvCache::Paged(_) => 0,
        }
    }

    /// Consume one token and return the logits it produces.  Fails once
    /// the session's pinned target length is reached.
    pub fn decode(&mut self, token: i32) -> Result<&[f32]> {
        let logits = decode_batch(
            self.model,
            std::slice::from_mut(&mut self.state),
            &[token],
            &mut self.scratch,
        )?;
        self.last_logits = logits.data;
        Ok(&self.last_logits)
    }

    /// Sample `n` tokens with `sampler`, feeding every sampled token
    /// (including the last) back through the decode path, so the model
    /// state always contains the returned stream and `generate` calls
    /// compose: a follow-up `generate`/`decode` continues from exactly
    /// the context the caller has seen.  Requires `prompt + n` to fit
    /// the session's target length.
    pub fn generate(
        &mut self,
        sampler: &crate::infer::Sampler,
        rng: &mut crate::util::rng::Rng,
        n: usize,
    ) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let sampled = sampler.sample(&self.last_logits, rng);
            let t = i32::try_from(sampled).expect("vocab fits i32");
            out.push(t);
            self.decode(t)?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::checkpoint::CkptMeta;
    use crate::coordinator::Backend;
    use crate::infer::Sampler;
    use crate::util::rng::Rng;

    fn rc(model: &str, mode: Mode) -> RunConfig {
        RunConfig {
            model: model.into(),
            mode,
            seed: 5,
            ..RunConfig::default()
        }
    }

    fn fresh_model(model: &str, mode: Mode) -> InferModel {
        let cfg = rc(model, mode);
        let backend = NativeBackend::new();
        let state = backend.init_state(&cfg).unwrap();
        InferModel::new(&cfg, state).unwrap()
    }

    #[test]
    fn prefill_plus_decode_matches_full_forward_spt() {
        // The kernel-level parity statement, session-level: logits after
        // prefill(p) + teacher-forced decode equal the training forward
        // over the whole sequence, row by row, bit for bit.
        let cfg = rc("spt-nano-l2", Mode::Spt);
        let backend = NativeBackend::new();
        let tstate = backend.init_state(&cfg).unwrap();
        let model = InferModel::new(&cfg, tstate.clone()).unwrap();
        let mut corpus = crate::data::SyntheticCorpus::new(model.vocab(), 4, 0.85, 3);
        let toks: Vec<i32> = corpus.sequence(24).iter().map(|&t| t as i32).collect();
        let full = backend.forward_logits(&cfg, &tstate, &toks).unwrap();
        let p = 9;
        let mut sess = Session::new(&model, &toks[..p], toks.len()).unwrap();
        assert_eq!(sess.logits(), full.row(p - 1), "prefill row");
        for (step, &t) in toks[p..].iter().enumerate() {
            let got = sess.decode(t).unwrap();
            assert_eq!(got, full.row(p + step), "decode row {}", p + step);
        }
        assert_eq!(sess.pos(), toks.len());
        assert!(sess.cache_bytes() > 0);
    }

    #[test]
    fn session_rejects_bad_shapes() {
        let model = fresh_model("spt-nano", Mode::Spt);
        assert!(Session::new(&model, &[], 8).is_err(), "empty prompt");
        assert!(Session::new(&model, &[1, 2, 3], 2).is_err(), "target < prompt");
        let too_long = model.max_seq() + 1;
        assert!(Session::new(&model, &[1, 2], too_long).is_err(), "target > max_seq");
    }

    #[test]
    fn decode_stops_at_the_pinned_target_length() {
        // L was pinned to the target; decoding past it would silently
        // void the parity contract, so it must fail loudly instead.
        let model = fresh_model("spt-nano", Mode::Spt);
        let mut sess = Session::new(&model, &[1, 2], 3).unwrap();
        sess.decode(5).unwrap(); // pos 2 -> 3 == target
        let err = sess.decode(6).unwrap_err();
        assert!(err.to_string().contains("target length"), "{err}");
        assert_eq!(sess.pos(), 3);
    }

    #[test]
    fn generate_composes_with_follow_up_generate() {
        // Every sampled token is fed back, so two generate(6) calls see
        // exactly the context of one generate(12) and produce the same
        // stream (same RNG draws).
        let model = fresh_model("spt-nano", Mode::Spt);
        let sampler = Sampler::TopK { k: 16, temperature: 0.8 };
        let mut one = Session::new(&model, &[1, 2, 3, 4], 16).unwrap();
        let mut rng1 = Rng::new(7);
        let whole = one.generate(&sampler, &mut rng1, 12).unwrap();
        let mut two = Session::new(&model, &[1, 2, 3, 4], 16).unwrap();
        let mut rng2 = Rng::new(7);
        let mut split = two.generate(&sampler, &mut rng2, 6).unwrap();
        split.extend(two.generate(&sampler, &mut rng2, 6).unwrap());
        assert_eq!(whole, split);
        assert_eq!(one.pos(), 16);
        assert_eq!(two.pos(), 16);
    }

    #[test]
    fn generate_is_deterministic_per_seed() {
        for mode in Mode::ALL {
            let model = fresh_model("spt-nano", mode);
            let run = |seed: u64| {
                let mut sess = Session::new(&model, &[1, 2, 3, 4], 20).unwrap();
                let sampler = Sampler::TopK { k: 16, temperature: 0.8 };
                let mut rng = Rng::new(seed);
                sess.generate(&sampler, &mut rng, 12).unwrap()
            };
            assert_eq!(run(42), run(42), "{mode:?}: same seed must agree");
            assert_ne!(run(42), run(43), "{mode:?}: seeds should diverge");
        }
    }

    #[test]
    fn checkpoint_identity_is_verified() {
        let cfg = rc("spt-nano", Mode::Spt);
        let backend = NativeBackend::new();
        let state = backend.init_state(&cfg).unwrap();
        let dir = std::env::temp_dir().join("spt_infer_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("id.ckpt");
        checkpoint::save_tagged(
            &state,
            &CkptMeta { model: "spt-nano".into(), mode: Mode::Spt, n_layers: 1 },
            &path,
        )
        .unwrap();
        assert!(InferModel::from_checkpoint(&cfg, &path).is_ok());
        let wrong_mode = rc("spt-nano", Mode::Full);
        let err = InferModel::from_checkpoint(&wrong_mode, &path).unwrap_err();
        assert!(err.to_string().contains("spt"), "{err}");
        let wrong_model = rc("spt-nano-l2", Mode::Spt);
        assert!(InferModel::from_checkpoint(&wrong_model, &path).is_err());
    }

    #[test]
    fn checkpoint_layer_count_is_verified() {
        // Same model/mode but a drifted depth tag: materialization can
        // succeed (the leaves are the preset's), so the post-build
        // verify_layers check is what must catch it.
        let cfg = rc("spt-nano", Mode::Spt);
        let backend = NativeBackend::new();
        let state = backend.init_state(&cfg).unwrap();
        let dir = std::env::temp_dir().join("spt_infer_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("depth.ckpt");
        checkpoint::save_tagged(
            &state,
            &CkptMeta { model: "spt-nano".into(), mode: Mode::Spt, n_layers: 2 },
            &path,
        )
        .unwrap();
        let err = InferModel::from_checkpoint(&cfg, &path).unwrap_err();
        assert!(err.to_string().contains("2 layers"), "{err}");
    }
}
