//! PJRT execution engine: load HLO-text artifacts, compile once, execute
//! from the coordinator's hot path.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* is the
//! interchange format (jax >= 0.5 protos have 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! Two execution surfaces:
//! * [`Engine::run`] — literals in, host tensors out.  Convenient; copies
//!   every operand host<->device per call.
//! * [`Engine::run_buffers`] / [`DeviceState`] — device buffers stay
//!   resident across steps (params/optimizer state in a training loop);
//!   only tokens/targets are uploaded per step and only the loss scalar is
//!   fetched.  This is the fast path the trainer uses.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::HostTensor;
use super::xla;

/// Cumulative per-artifact execution statistics (Table 5's kernel
/// breakdown is assembled from these).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// The PJRT engine: one CPU client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<BTreeMap<String, ExecStats>>,
}

impl Engine {
    /// Create a CPU-PJRT engine over an artifact directory.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(BTreeMap::new()),
            stats: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Compile (or fetch cached) executable for an artifact.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.get(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let exe = std::sync::Arc::new(exe);
        let dt = t0.elapsed().as_secs_f64();
        self.stats
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .compile_secs += dt;
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Validate inputs against the manifest signature.
    fn check_inputs(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if !t.matches(s) {
                bail!(
                    "artifact '{}' input {} ({}): expected {:?} {:?}, got {:?} {:?}",
                    spec.name,
                    i,
                    spec.input_paths.get(i).map(String::as_str).unwrap_or("?"),
                    s.dtype,
                    s.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        Ok(())
    }

    /// Execute with host tensors (checked against the manifest signature).
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.get(name)?.clone();
        self.check_inputs(&spec, inputs)?;
        let exe = self.load(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?;
        let out = Self::collect_outputs(&result)?;
        self.record(name, t0.elapsed().as_secs_f64());
        if out.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}': manifest declares {} outputs, runtime produced {}",
                spec.outputs.len(),
                out.len()
            );
        }
        Ok(out)
    }

    /// Execute with device-resident buffers; returns output buffers
    /// without copying them to the host.
    pub fn run_buffers(
        &self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let mut result = exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        self.record(name, t0.elapsed().as_secs_f64());
        if result.len() != 1 {
            bail!("expected single-replica execution");
        }
        Ok(result.remove(0))
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let lit = t.to_literal()?;
        self.client
            .buffer_from_host_literal(None, &lit)
            .context("buffer_from_host_literal")
    }

    /// Download a device buffer (decomposing the jax 1-tuple convention is
    /// the caller's job via `collect_outputs` when using `run`).
    pub fn download(&self, buf: &xla::PjRtBuffer) -> Result<HostTensor> {
        let lit = buf.to_literal_sync()?;
        HostTensor::from_literal(&lit)
    }

    fn collect_outputs(
        result: &[Vec<xla::PjRtBuffer>],
    ) -> Result<Vec<HostTensor>> {
        if result.len() != 1 {
            bail!("expected single replica, got {}", result.len());
        }
        let bufs = &result[0];
        // aot.py lowers with return_tuple=True: one tuple buffer that
        // to_literal_sync materializes as a tuple literal.
        if bufs.len() == 1 {
            let mut lit = bufs[0].to_literal_sync()?;
            let shape = lit.shape()?;
            if matches!(shape, xla::Shape::Tuple(_)) {
                let parts = lit.decompose_tuple()?;
                return parts
                    .iter()
                    .map(HostTensor::from_literal)
                    .collect::<Result<_>>();
            }
            return Ok(vec![HostTensor::from_literal(&lit)?]);
        }
        bufs.iter()
            .map(|b| self_download(b))
            .collect::<Result<_>>()
    }

    fn record(&self, name: &str, secs: f64) {
        let mut stats = self.stats.lock().unwrap();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_secs += secs;
    }

    /// Snapshot of per-artifact execution stats.
    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<_> = self
            .stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.total_secs.total_cmp(&a.1.total_secs));
        v
    }

    pub fn reset_stats(&self) {
        self.stats.lock().unwrap().clear();
    }
}

fn self_download(buf: &xla::PjRtBuffer) -> Result<HostTensor> {
    let lit = buf.to_literal_sync()?;
    HostTensor::from_literal(&lit)
}

/// Device-resident training state: params + optimizer buffers that stay on
/// the device between steps (the fast path).
pub struct DeviceState {
    pub buffers: Vec<xla::PjRtBuffer>,
}

impl DeviceState {
    pub fn from_host(engine: &Engine, tensors: &[HostTensor]) -> Result<Self> {
        let buffers = tensors
            .iter()
            .map(|t| engine.upload(t))
            .collect::<Result<_>>()?;
        Ok(DeviceState { buffers })
    }

    pub fn to_host(&self, engine: &Engine) -> Result<Vec<HostTensor>> {
        self.buffers.iter().map(|b| engine.download(b)).collect()
    }

    pub fn len(&self) -> usize {
        self.buffers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.is_empty()
    }
}
