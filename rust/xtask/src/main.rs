//! `cargo xtask` — workspace automation, wired up through the alias in
//! `rust/.cargo/config.toml`.
//!
//! One task so far: `detlint`, the determinism lint pass described in
//! `detlint.rs` and in README's "Determinism contract" section.  Run it
//! as `cargo xtask detlint` (defaults to the spt crate's `src/`) or
//! `cargo xtask detlint path/to/file.rs dir/` to lint specific paths.

use std::path::PathBuf;
use std::process::ExitCode;

mod detlint;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let Some(cmd) = args.next() else {
        eprintln!("usage: cargo xtask detlint [paths...]");
        return ExitCode::FAILURE;
    };
    match cmd.as_str() {
        "detlint" => detlint::run(&args.map(PathBuf::from).collect::<Vec<_>>()),
        other => {
            eprintln!("unknown xtask '{other}' (available: detlint)");
            ExitCode::FAILURE
        }
    }
}
