//! Leveled stderr logger — the single diagnostics channel.
//!
//! Every diagnostic the CLI, trainer, daemon, checkpoint scanner, or
//! pid-lock emits goes through the `log_error!` / `log_warn!` /
//! `log_info!` / `log_debug!` macros, which write one line to **stderr**
//! in the form
//!
//! ```text
//! [spt][info] daemon listening addr=127.0.0.1:7199
//! ```
//!
//! so stdout stays reserved for *data* output: result tables, the
//! daemon's NDJSON protocol lines, generated text, bench JSON paths,
//! and loss curves.  By convention messages end with a space-separated
//! `key=value` tail carrying the structured fields.
//!
//! The threshold comes from `SPT_LOG` (`error|warn|info|debug`), read
//! once per process; unset or unrecognized values mean `info`.  Logging
//! formats already-computed values on sequential control paths only —
//! it can never feed back into computed results.

use std::sync::OnceLock;

/// Severity levels, most severe first (`Error < Warn < Info < Debug`
/// in the derived order, so `l <= threshold` is the emit test).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse an `SPT_LOG` value; `None` for anything unrecognized.
    pub fn parse(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            _ => return None,
        })
    }
}

static THRESHOLD: OnceLock<Level> = OnceLock::new();

/// The process-wide threshold (`SPT_LOG`, default `info`), read once.
pub fn threshold() -> Level {
    *THRESHOLD.get_or_init(|| {
        std::env::var("SPT_LOG").ok().as_deref().and_then(Level::parse).unwrap_or(Level::Info)
    })
}

/// Would a message at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    level <= threshold()
}

/// Emit one diagnostic line on stderr (no-op above the threshold).
/// Callers use the `log_*!` macros rather than calling this directly.
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[spt][{}] {}", level.as_str(), args);
    }
}

/// `log_error!("message key={value}")` — always emitted.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*))
    };
}

/// `log_warn!("message key={value}")` — degraded-but-continuing paths.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*))
    };
}

/// `log_info!("message key={value}")` — normal operational diagnostics.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*))
    };
}

/// `log_debug!("message key={value}")` — verbose tracing, off by default.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_most_severe_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn parse_accepts_known_names_case_insensitively() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("Info"), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("trace"), None);
        assert_eq!(Level::parse(""), None);
    }

    #[test]
    fn threshold_gates_by_severity() {
        // Whatever SPT_LOG says, errors are always emitted and the
        // enabled set is a severity-prefix of the level order.
        assert!(enabled(Level::Error));
        let t = threshold();
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(enabled(l), l <= t);
        }
    }
}
