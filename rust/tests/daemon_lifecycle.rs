//! Daemon lifecycle integration: overload behavior, drain completeness,
//! and the bit-identical-under-load serving contract.
//!
//! The acceptance bar: under overload the daemon rejects with
//! *structured* errors (never silent drops), every stream it does
//! accept is bit-identical to what an unloaded [`ServeDriver`] would
//! have generated for the same request, and a graceful drain produces a
//! complete final report.

use std::sync::Arc;

use spt::config::{Mode, RunConfig};
use spt::coordinator::{Backend, NativeBackend};
use spt::infer::{Daemon, DaemonConfig, InferModel, Request, ServeConfig, ServeDriver};
use spt::util::fault::FaultPlan;
use spt::util::json::{self, Json};

const SEED: u64 = 42;

fn model() -> InferModel {
    let rc = RunConfig {
        model: "spt-nano".into(),
        mode: Mode::Spt,
        seed: 7,
        ..RunConfig::default()
    };
    let backend = NativeBackend::new();
    let state = backend.init_state(&rc).unwrap();
    InferModel::new(&rc, state).unwrap()
}

fn submit_line(id: usize, prompt: &[i32], max_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!(
        r#"{{"op":"submit","id":{id},"prompt":[{}],"max_new_tokens":{max_new}}}"#,
        toks.join(",")
    )
}

fn kind(e: &Json) -> &str {
    e.get("event").as_str().unwrap_or("?")
}

fn prompt_for(id: usize) -> Vec<i32> {
    vec![1 + id as i32, 2, 3, 4]
}

/// Unloaded reference: one request alone through a fresh driver with
/// the same seed — the stream the daemon must reproduce under load.
fn solo_tokens(m: &InferModel, id: usize, max_new: usize) -> Vec<i32> {
    let cfg = ServeConfig { max_batch: 1, seed: SEED, ..ServeConfig::default() };
    let mut driver = ServeDriver::new(m, cfg).unwrap();
    driver
        .submit(Request { id, prompt: prompt_for(id), max_new_tokens: max_new })
        .unwrap();
    let report = driver.run_to_completion().unwrap();
    report.completions[0].tokens.clone()
}

#[test]
fn overload_rejects_structured_and_served_streams_match_unloaded_driver() {
    let m = model();
    let cfg = DaemonConfig {
        serve: ServeConfig { max_batch: 2, seed: SEED, ..ServeConfig::default() },
        queue_cap: 3,
        ..DaemonConfig::default()
    };
    let mut d = Daemon::new(&m, cfg).unwrap();
    // Burst of 6 submissions against a queue of 3: the overflow must be
    // rejected with a structured queue_full error, not dropped.
    let mut accepted = Vec::new();
    let mut rejected = Vec::new();
    for id in 0..6 {
        let ev = d.handle_line(&submit_line(id, &prompt_for(id), 5));
        assert_eq!(ev.len(), 1);
        match kind(&ev[0]) {
            "accepted" => accepted.push(id),
            "rejected" => {
                assert_eq!(ev[0].get("code").as_str(), Some("queue_full"));
                assert_eq!(ev[0].get("id").as_usize(), Some(id));
                rejected.push(id);
            }
            other => panic!("unexpected event {other}"),
        }
    }
    assert_eq!(accepted, vec![0, 1, 2], "queue admits in order to capacity");
    assert_eq!(rejected, vec![3, 4, 5]);
    // Drain and collect the done events.
    let (events, report) = d.finish().unwrap();
    let done: Vec<&Json> = events.iter().filter(|e| kind(e) == "done").collect();
    assert_eq!(done.len(), 3, "every accepted request completes");
    assert_eq!(report.completions.len(), 3);
    assert_eq!(report.failed, 0);
    // Each stream served under load is bit-identical to the same
    // request alone on an unloaded driver (per-request RNG streams).
    for c in &report.completions {
        assert_eq!(
            c.tokens,
            solo_tokens(&m, c.id, 5),
            "request {} diverged under load",
            c.id
        );
    }
}

#[test]
fn drain_finishes_in_flight_work_and_reports_completely() {
    let m = model();
    let mut d = Daemon::new(&m, DaemonConfig::default()).unwrap();
    for id in 0..4 {
        assert_eq!(kind(&d.handle_line(&submit_line(id, &prompt_for(id), 6))[0]), "accepted");
    }
    // Get some work in flight before draining.
    d.pump().unwrap();
    d.begin_drain();
    // New work is refused once draining...
    let ev = d.handle_line(&submit_line(99, &prompt_for(99), 2));
    assert_eq!(ev[0].get("code").as_str(), Some("draining"));
    // ...but everything already accepted runs to completion.
    let (events, report) = d.finish().unwrap();
    assert_eq!(report.completions.len(), 4);
    assert_eq!(report.failed, 0);
    for c in &report.completions {
        assert_eq!(c.tokens.len(), 6, "request {} truncated by drain", c.id);
    }
    let report_ev = events.last().unwrap();
    assert_eq!(kind(report_ev), "report");
    assert_eq!(report_ev.get("completed").as_usize(), Some(4));
    assert_eq!(report_ev.get("failed").as_usize(), Some(0));
    assert_eq!(
        report_ev.get("generated_tokens").as_usize(),
        Some(24),
        "4 requests x 6 tokens, all accounted for in the final report"
    );
}

#[test]
fn stdio_script_runs_the_full_lifecycle_with_clean_ndjson_output() {
    let m = model();
    let mut d = Daemon::new(
        &m,
        DaemonConfig {
            serve: ServeConfig { max_batch: 2, seed: SEED, ..ServeConfig::default() },
            queue_cap: 2,
            ..DaemonConfig::default()
        },
    )
    .unwrap();
    let script = format!(
        "{}\n{}\n{}\ngarbage line\n{{\"op\":\"status\"}}\n{{\"op\":\"drain\"}}\n",
        submit_line(0, &prompt_for(0), 4),
        submit_line(1, &prompt_for(1), 4),
        submit_line(2, &prompt_for(2), 4), // queue_cap 2: rejected
    );
    let mut out: Vec<u8> = Vec::new();
    let report = d
        .serve_stream(std::io::Cursor::new(script.into_bytes()), &mut out, true)
        .unwrap()
        .expect("drain produces a report");
    assert_eq!(report.failed, 0);
    let text = String::from_utf8(out).unwrap();
    let events: Vec<Json> = text
        .lines()
        .map(|l| json::parse(l).unwrap_or_else(|e| panic!("non-JSON output line {l:?}: {e}")))
        .collect();
    let kinds: Vec<&str> = events.iter().map(kind).collect();
    assert_eq!(kinds.iter().filter(|k| **k == "accepted").count(), 2);
    assert_eq!(kinds.iter().filter(|k| **k == "rejected").count(), 1);
    assert_eq!(kinds.iter().filter(|k| **k == "error").count(), 1, "garbage degraded");
    assert_eq!(kinds.iter().filter(|k| **k == "done").count(), 2);
    assert_eq!(*kinds.last().unwrap(), "report");
    // Accepted streams match the unloaded driver even in stream mode.
    for ev in events.iter().filter(|e| kind(e) == "done") {
        let id = ev.get("id").as_usize().unwrap();
        let tokens: Vec<i32> = ev
            .get("tokens")
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| i32::try_from(t.as_i64().unwrap()).unwrap())
            .collect();
        assert_eq!(tokens, solo_tokens(&m, id, 4), "request {id}");
    }
}

#[test]
fn deadline_cancellation_does_not_perturb_surviving_streams() {
    let m = model();
    let cfg = DaemonConfig {
        serve: ServeConfig { max_batch: 4, seed: SEED, ..ServeConfig::default() },
        deadline_steps: Some(4),
        ..DaemonConfig::default()
    };
    let mut d = Daemon::new(&m, cfg).unwrap();
    // Request 0 wants more decode steps than the deadline allows; 1 and
    // 2 fit comfortably.
    d.handle_line(&submit_line(0, &prompt_for(0), 10));
    d.handle_line(&submit_line(1, &prompt_for(1), 3));
    d.handle_line(&submit_line(2, &prompt_for(2), 3));
    let (_, report) = d.finish().unwrap();
    assert_eq!(report.completions.len(), 3);
    assert_eq!(report.failed, 1);
    let cancelled = &report.completions[0];
    assert!(cancelled.error.as_deref().unwrap_or("").contains("deadline"));
    assert!(!cancelled.tokens.is_empty(), "partial output preserved");
    for c in report.completions.iter().filter(|c| c.error.is_none()) {
        assert_eq!(c.tokens, solo_tokens(&m, c.id, 3), "survivor {} diverged", c.id);
    }
}

#[test]
fn fault_plan_rejections_are_deterministic_across_runs() {
    let m = model();
    let run = || -> Vec<String> {
        let plan = Arc::new(FaultPlan::new().with("queue_full", 3));
        let cfg = DaemonConfig { fault: Some(plan), ..DaemonConfig::default() };
        let mut d = Daemon::new(&m, cfg).unwrap();
        let mut outcomes = Vec::new();
        for id in 0..5 {
            let ev = d.handle_line(&submit_line(id, &prompt_for(id), 2));
            outcomes.push(format!("{}:{}", id, kind(&ev[0])));
        }
        let (_, report) = d.finish().unwrap();
        outcomes.push(format!("completed:{}", report.completions.len()));
        outcomes
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "seeded fault plan must reproduce exactly");
    assert_eq!(a[2], "2:rejected", "3rd probe fires the injected queue_full");
    assert_eq!(a.last().unwrap(), "completed:4");
}
