//! The determinism lint pass (`cargo xtask detlint`).
//!
//! The repo's signature property is that gradients, params, and decode
//! streams are bit-identical at any rayon pool size.  That contract is
//! easy to break silently — one unordered map iteration or reordered
//! float reduction — so this pass turns it into machine-checked rules
//! over the spt crate's sources:
//!
//! * `hash-order` — `HashMap`/`HashSet` anywhere in `src/`: their
//!   iteration order is hash-seeded, so any use risks order reaching an
//!   output.  Use `BTreeMap`/`BTreeSet` or collect-and-sort; justify a
//!   genuinely order-free use with `// det: hash-ok`.
//! * `par-merge-order` — `.reduce(`/`.fold(` chained onto a parallel
//!   iterator: the merge tree is the scheduler's, so the result depends
//!   on thread count unless the operation is exactly associative.
//!   Justify with `// det: merge-order`.  Sequential folds, including
//!   ones inside the body of a parallel closure, are not flagged: only
//!   the statement that starts the parallel chain is scanned.
//! * `wall-clock` — `Instant`/`SystemTime`/`thread_rng` in kernel code
//!   (`sparse/`, `infer/`, `coordinator/`): time and ambient randomness
//!   are nondeterministic inputs.  Timing that only reaches reports is
//!   fine — justify with `// det: wall-clock`.
//! * `trunc-cast` — `as u8/u16/u32/i8/i16/i32` applied to a computed
//!   expression (a `)`, `]`, or `?` immediately before the cast):
//!   silent truncation on index arithmetic corrupts sparse structures
//!   three kernels away from the cause.  Prefer `try_from`; justify a
//!   provably bounded cast with `// det: cast-bounded`.  Casts of plain
//!   identifiers and all widening/float casts are exempt.
//! * `obs-placement` — observability hooks (`obs::`, `ObsLog`,
//!   `PhaseTimes`, `StepObs`) inside `sparse/` kernel code: timing and
//!   telemetry belong at the sequential step boundaries in
//!   `coordinator/` and `infer/`, never in the parallel inner loops,
//!   where a probe could perturb scheduling or tempt a clock read.
//!   Justify a genuinely inert use with `// det: obs-ok`.
//!
//! A marker counts on the offending line or on either of the two lines
//! above it.  The rules are lexical by design — no syn, no build, runs
//! in milliseconds — and the fixture tests below pin each rule's
//! behavior, including marker suppression and string/comment stripping.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Directories whose sources count as kernel code for the wall-clock
/// rule: the hot paths where ambient time or randomness could reach
/// results.
const KERNEL_DIRS: [&str; 3] = ["sparse", "infer", "coordinator"];

/// Tokens that start a parallel iterator chain.
const PAR_TRIGGERS: [&str; 5] = [
    "par_iter(",
    "into_par_iter(",
    "par_chunks(",
    "par_chunks_mut(",
    "par_bridge(",
];

/// Order-sensitive merge adaptors (checked only inside a parallel chain).
const MERGE_OPS: [&str; 2] = [".reduce(", ".fold("];

/// Wall-clock / ambient-randomness tokens (kernel code only).
const CLOCK_TOKENS: [&str; 4] = ["Instant", "SystemTime", "thread_rng", "rand::random"];

/// Hash-seeded containers (flagged anywhere).
const HASH_TOKENS: [&str; 2] = ["HashMap", "HashSet"];

/// Truncating integer cast targets.  `usize`/`u64`/`i64` and the float
/// types are exempt: on 64-bit targets they cannot truncate the index
/// arithmetic this rule is after.
const CAST_TARGETS: [&str; 6] = [" as u8", " as u16", " as u32", " as i8", " as i16", " as i32"];

/// Observability hooks (flagged in `sparse/` kernel code only — timing
/// belongs at the sequential step boundaries in `coordinator/`/`infer/`).
const OBS_TOKENS: [&str; 4] = ["obs::", "ObsLog", "PhaseTimes", "StepObs"];

pub const MARKER_HASH: &str = "det: hash-ok";
pub const MARKER_MERGE: &str = "det: merge-order";
pub const MARKER_CLOCK: &str = "det: wall-clock";
pub const MARKER_CAST: &str = "det: cast-bounded";
pub const MARKER_OBS: &str = "det: obs-ok";

/// How many lines above a violation its `// det:` marker may sit.
const MARKER_WINDOW: usize = 2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    HashOrder,
    ParMergeOrder,
    WallClock,
    TruncCast,
    ObsPlacement,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::HashOrder => "hash-order",
            Rule::ParMergeOrder => "par-merge-order",
            Rule::WallClock => "wall-clock",
            Rule::TruncCast => "trunc-cast",
            Rule::ObsPlacement => "obs-placement",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Violation {
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    /// The offending line, trimmed, for the report.
    pub excerpt: String,
}

/// Run the pass over `paths` (files or directories; empty means the spt
/// crate's `src/`).  Prints violations and returns the exit code.
pub fn run(paths: &[PathBuf]) -> ExitCode {
    let roots: Vec<PathBuf> = if paths.is_empty() {
        let xtask_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        vec![xtask_dir.parent().expect("xtask has a parent dir").join("src")]
    } else {
        paths.to_vec()
    };
    let mut files = Vec::new();
    for root in &roots {
        collect_rs_files(root, &mut files);
    }
    files.sort();
    files.dedup();
    let mut total = 0usize;
    for file in &files {
        let src = match std::fs::read_to_string(file) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("detlint: cannot read {}: {e}", file.display());
                return ExitCode::FAILURE;
            }
        };
        for v in lint_source(&src, is_kernel_path(file), is_sparse_path(file)) {
            println!("{}:{}: [{}] {}", file.display(), v.line, v.rule.name(), v.excerpt);
            total += 1;
        }
    }
    if total == 0 {
        println!("detlint: {} files clean", files.len());
        ExitCode::SUCCESS
    } else {
        println!("detlint: {total} violation(s)");
        ExitCode::FAILURE
    }
}

/// Whether `path` falls under one of the kernel directories.
fn is_kernel_path(path: &Path) -> bool {
    path.components()
        .any(|c| KERNEL_DIRS.iter().any(|d| c.as_os_str() == *d))
}

/// Whether `path` is sparse-kernel code, where the obs-placement rule
/// bans observability hooks outright.
fn is_sparse_path(path: &Path) -> bool {
    path.components().any(|c| c.as_os_str() == "sparse")
}

/// Recursively collect `.rs` files, visiting entries in sorted order so
/// the report itself is deterministic.
fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) {
    if root.is_file() {
        if root.extension().is_some_and(|e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return;
    }
    let Ok(entries) = std::fs::read_dir(root) else {
        return;
    };
    let mut children: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    children.sort();
    for child in children {
        collect_rs_files(&child, out);
    }
}

/// Lint one source file.  `kernel` enables the wall-clock rule;
/// `sparse` additionally enables the obs-placement rule.
pub fn lint_source(src: &str, kernel: bool, sparse: bool) -> Vec<Violation> {
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    // True while inside the statement that started a parallel chain;
    // cleared when that statement ends.
    let mut par_chain = false;
    for (ix, raw) in lines.iter().enumerate() {
        let code = strip_strings_and_comments(raw);
        if HASH_TOKENS.iter().any(|t| code.contains(t)) && !marked(&lines, ix, MARKER_HASH) {
            out.push(violation(ix, raw, Rule::HashOrder));
        }
        if kernel
            && CLOCK_TOKENS.iter().any(|t| code.contains(t))
            && !marked(&lines, ix, MARKER_CLOCK)
        {
            out.push(violation(ix, raw, Rule::WallClock));
        }
        if sparse
            && OBS_TOKENS.iter().any(|t| code.contains(t))
            && !marked(&lines, ix, MARKER_OBS)
        {
            out.push(violation(ix, raw, Rule::ObsPlacement));
        }
        // par-merge-order: a reduce/fold anywhere between a parallel
        // trigger and the end of that statement.  On the trigger line
        // itself, only positions at or after the trigger count, so a
        // sequential fold earlier on the line stays exempt.
        let trigger_at = PAR_TRIGGERS.iter().filter_map(|t| code.find(t)).min();
        let scan_from = if par_chain { Some(0) } else { trigger_at };
        if let Some(from) = scan_from {
            if MERGE_OPS.iter().any(|t| code[from..].contains(t))
                && !marked(&lines, ix, MARKER_MERGE)
            {
                out.push(violation(ix, raw, Rule::ParMergeOrder));
            }
        }
        if trigger_at.is_some() {
            par_chain = true;
        }
        if par_chain && statement_ends(code.trim_end()) {
            par_chain = false;
        }
        for t in CAST_TARGETS {
            for (at, _) in code.match_indices(t) {
                let next = code[at + t.len()..].chars().next();
                if matches!(next, Some(c) if c.is_ascii_alphanumeric() || c == '_') {
                    continue; // longer identifier, not a cast to this type
                }
                let prev = code[..at].trim_end().chars().last();
                if matches!(prev, Some(')' | ']' | '?')) && !marked(&lines, ix, MARKER_CAST) {
                    out.push(violation(ix, raw, Rule::TruncCast));
                }
            }
        }
    }
    out
}

fn violation(ix: usize, raw: &str, rule: Rule) -> Violation {
    Violation { line: ix + 1, rule, excerpt: raw.trim().to_string() }
}

/// Whether `marker` appears on line `ix` or within the window above it.
fn marked(lines: &[&str], ix: usize, marker: &str) -> bool {
    let lo = ix.saturating_sub(MARKER_WINDOW);
    lines[lo..=ix].iter().any(|l| l.contains(marker))
}

/// A parallel chain's statement is over at `;`, or at a closing brace
/// ending a block-expression statement.
fn statement_ends(code: &str) -> bool {
    code.ends_with(';') || code.ends_with('}')
}

/// Strip string literals and the trailing `//` comment from one line so
/// rule tokens inside strings or prose never fire.  Lexically
/// approximate — multi-line and raw strings are not tracked — which is
/// fine here: no rule token legitimately spans lines in this codebase.
fn strip_strings_and_comments(line: &str) -> String {
    let mut out = String::with_capacity(line.len());
    let mut chars = line.chars().peekable();
    let mut in_str = false;
    while let Some(c) = chars.next() {
        if in_str {
            match c {
                '\\' => {
                    chars.next();
                }
                '"' => in_str = false,
                _ => {}
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '/' if chars.peek() == Some(&'/') => break,
            _ => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(src: &str, kernel: bool) -> Vec<Rule> {
        lint_source(src, kernel, false).into_iter().map(|v| v.rule).collect()
    }

    /// Lint as sparse-kernel code (kernel + obs-placement rules on).
    fn sparse_rules(src: &str) -> Vec<Rule> {
        lint_source(src, true, true).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn hash_container_is_flagged_anywhere() {
        let src = "use std::collections::HashMap;\nlet m = HashSet::new();\n";
        assert_eq!(rules(src, false), vec![Rule::HashOrder, Rule::HashOrder]);
    }

    #[test]
    fn hash_marker_suppresses() {
        let src = "let m = HashMap::new(); // det: hash-ok (lookup only)\n";
        assert!(rules(src, false).is_empty());
    }

    #[test]
    fn hash_in_string_or_comment_is_ignored() {
        let src = "// a HashMap would break this\nlet s = \"HashMap\";\n";
        assert!(rules(src, false).is_empty());
    }

    #[test]
    fn par_fold_same_line_is_flagged() {
        let src = "let s = xs.par_iter().fold(|| 0.0f32, |a, &b| a + b);\n";
        assert_eq!(rules(src, false), vec![Rule::ParMergeOrder]);
    }

    #[test]
    fn par_reduce_across_chain_lines_is_flagged() {
        let src = "let s = xs\n    .par_iter()\n    .map(|x| x * 2.0)\n    .reduce(|| 0.0, f32::max);\n";
        assert_eq!(rules(src, false), vec![Rule::ParMergeOrder]);
    }

    #[test]
    fn par_merge_marker_suppresses() {
        let src =
            "// det: merge-order (max is associative)\nlet s = xs.par_iter().reduce(|| 0.0, f32::max);\n";
        assert!(rules(src, false).is_empty());
    }

    #[test]
    fn sequential_fold_is_fine() {
        let src = "let mx = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);\n";
        assert!(rules(src, false).is_empty());
    }

    #[test]
    fn fold_after_par_statement_ends_is_fine() {
        // The chain's statement ended; a later sequential fold in the
        // same function must not inherit the parallel context.
        let src = "ys.par_iter().for_each(|y| sink(y));\nlet mx = vals.iter().fold(0.0f32, f32::max);\n";
        assert!(rules(src, false).is_empty());
    }

    #[test]
    fn sequential_fold_inside_par_closure_body_is_fine() {
        // Mirrors the attention kernels: a par_chunks_mut loop whose
        // per-chunk body runs an ordered sequential fold.
        let src = "out.par_chunks_mut(n)\n    .enumerate()\n    .for_each(|(ci, chunk)| {\n        let row0 = ci * n;\n        let mx = chunk.iter().cloned().fold(f32::MIN, f32::max);\n        chunk[0] = mx + row0 as f32;\n    });\n";
        assert!(rules(src, false).is_empty());
    }

    #[test]
    fn wall_clock_flagged_in_kernel_code_only() {
        let src = "let t0 = Instant::now();\n";
        assert_eq!(rules(src, true), vec![Rule::WallClock]);
        assert!(rules(src, false).is_empty());
    }

    #[test]
    fn wall_clock_marker_suppresses() {
        let src = "let t0 = Instant::now(); // det: wall-clock (report timing)\n";
        assert!(rules(src, true).is_empty());
    }

    #[test]
    fn thread_rng_is_flagged_in_kernel_code() {
        let src = "let x = rand::thread_rng().gen::<f32>();\n";
        assert_eq!(rules(src, true), vec![Rule::WallClock]);
    }

    #[test]
    fn computed_truncating_cast_is_flagged() {
        for line in [
            "let p = (r * l) as u32;",
            "let n = flat.len() as u32;",
            "let t = sampler.sample(&logits, rng) as i32;",
            "let c = idx[i] as u16;",
            "let s = total()? as i8;",
        ] {
            assert_eq!(rules(line, false), vec![Rule::TruncCast], "{line}");
        }
    }

    #[test]
    fn plain_variable_and_widening_casts_are_fine() {
        for line in [
            "let p = j as u32;",
            "let w = x as f32;",
            "let u = idx.len() as u64;",
            "let z = n.min(m) as usize;",
            "let q = (a + b) as usize;",
            "let s = score(q, k) as i64;",
        ] {
            assert!(rules(line, false).is_empty(), "{line}");
        }
    }

    #[test]
    fn cast_marker_suppresses() {
        let src = "// det: cast-bounded (e <= 256)\nlet c = pick(e) as u8;\n";
        assert!(rules(src, false).is_empty());
    }

    #[test]
    fn marker_window_is_two_lines() {
        let src = "// det: cast-bounded\n//\n//\nlet c = pick(e) as u8;\n";
        assert_eq!(rules(src, false), vec![Rule::TruncCast]);
    }

    #[test]
    fn violation_reports_line_and_excerpt() {
        let src = "let ok = 1;\nlet bad = items.len() as u32;\n";
        let vs = lint_source(src, false, false);
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].line, 2);
        assert_eq!(vs[0].excerpt, "let bad = items.len() as u32;");
    }

    #[test]
    fn register_blocked_kernel_fixture() {
        // The register-blocked microkernel's shape (sparse/kernel.rs):
        // fixed-width accumulator tiles, ascending-k loop, separate
        // mul/add — clean under the kernel rules as written.
        let clean = "let mut acc = [[0.0f32; LANES]; MR];\n\
                     for kk in kb..kend {\n\
                         let brow = &panel[kk * w + j..kk * w + j + LANES];\n\
                         for (o, &bv) in acc[0].iter_mut().zip(brow) {\n\
                             let prod = av * bv;\n\
                             *o += prod;\n\
                         }\n\
                     }\n";
        assert!(rules(clean, true).is_empty());
        // The truncating-cast rule still bites on computed panel
        // arithmetic in the same loop shape, and the marker suppresses.
        let cast = "let lane = (kk * w + j) as u32;\n";
        assert_eq!(rules(cast, true), vec![Rule::TruncCast]);
        let suppressed =
            "// det: cast-bounded (panel index fits u32)\nlet lane = (kk * w + j) as u32;\n";
        assert!(rules(suppressed, true).is_empty());
        // A parallel merge over kernel tiles without a marker is flagged:
        // tile results must combine in a fixed order.
        let par =
            "let s = tiles.par_iter().map(run_tile).reduce(|| 0.0f32, |a, b| a + b);\n";
        assert_eq!(rules(par, true), vec![Rule::ParMergeOrder]);
    }

    #[test]
    fn kernel_path_detection() {
        assert!(is_kernel_path(Path::new("src/sparse/csr.rs")));
        assert!(is_kernel_path(Path::new("src/sparse/kernel.rs")));
        assert!(is_kernel_path(Path::new("/abs/src/infer/serve.rs")));
        assert!(is_kernel_path(Path::new("src/coordinator/native.rs")));
        assert!(!is_kernel_path(Path::new("src/runtime/engine.rs")));
        assert!(!is_kernel_path(Path::new("src/data/corpus.rs")));
    }

    #[test]
    fn sparse_path_detection() {
        assert!(is_sparse_path(Path::new("src/sparse/kernel.rs")));
        assert!(is_sparse_path(Path::new("/abs/src/sparse/bspmv.rs")));
        assert!(!is_sparse_path(Path::new("src/coordinator/native.rs")));
        assert!(!is_sparse_path(Path::new("src/infer/serve.rs")));
        assert!(!is_sparse_path(Path::new("src/obs/mod.rs")));
    }

    #[test]
    fn obs_hooks_flagged_in_sparse_code_only() {
        // Seeded violations: each obs token fires in sparse code.
        for line in [
            "let d = crate::obs::model_err(a, b);",
            "let mut log = ObsLog::disabled();",
            "let mut pt = PhaseTimes::new();",
            "let mut sobs = StepObs::default();",
        ] {
            assert_eq!(sparse_rules(line), vec![Rule::ObsPlacement], "{line}");
            // The same line is legal at the coordinator/infer step
            // boundaries (kernel dirs, but not sparse/).
            assert!(rules(line, true).is_empty(), "{line}");
        }
    }

    #[test]
    fn obs_marker_suppresses_and_window_holds() {
        let marked = "// det: obs-ok (constant lookup, no timing)\nlet d = obs::SCHEMA_VERSION;\n";
        assert!(sparse_rules(marked).is_empty());
        let too_far = "// det: obs-ok\n//\n//\nlet d = obs::SCHEMA_VERSION;\n";
        assert_eq!(sparse_rules(too_far), vec![Rule::ObsPlacement]);
    }

    #[test]
    fn obs_in_string_or_comment_is_ignored_in_sparse_code() {
        let src = "// a PhaseTimes here would be a bug\nlet s = \"obs::ObsLog\";\n";
        assert!(sparse_rules(src).is_empty());
    }

    #[test]
    fn obs_timing_in_sparse_inner_loop_fixture_is_flagged() {
        // The shape this rule exists to catch: a probe inside the
        // register-blocked GEMM loop.  Both the clock read and the obs
        // hook fire.
        let src = "for kk in kb..kend {\n    pt.time(\"tile\", || run_tile(kk));\n    let t = PhaseTimes::new();\n}\n";
        assert_eq!(sparse_rules(src), vec![Rule::ObsPlacement]);
    }

    #[test]
    fn repo_sources_are_clean() {
        // The real tree must hold the contract the fixtures above pin
        // down: run the production path over `../src`.
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("workspace root")
            .join("src");
        let mut files = Vec::new();
        collect_rs_files(&root, &mut files);
        assert!(files.len() > 20, "expected the spt sources under {}", root.display());
        let mut bad = Vec::new();
        for f in files {
            let src = std::fs::read_to_string(&f).expect("readable source");
            for v in lint_source(&src, is_kernel_path(&f), is_sparse_path(&f)) {
                bad.push(format!("{}:{}: [{}] {}", f.display(), v.line, v.rule.name(), v.excerpt));
            }
        }
        assert!(bad.is_empty(), "detlint violations:\n{}", bad.join("\n"));
    }
}
