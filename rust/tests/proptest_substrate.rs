//! Cross-module property tests over the rust-native substrate:
//! the paper's algorithmic invariants, checked end to end.

use spt::sparse::{attention, bspmv, csr::Csr, naive_pq, pq, topl, Matrix};
use spt::util::proptest::{check, prop_assert};

#[test]
fn bucket_sort_agrees_with_naive_pq_on_match_counts() {
    // When ADC tables degenerate to the indicator metric (orthonormal
    // equal-norm codewords), bucket sort and Naive-PQ rank identically.
    // With general codebooks we instead check the *contract*: both return
    // L unique in-range keys and bucket sort's ranking is exactly
    // (-match_score, index).
    check(40, |g| {
        let n = g.usize_in(4, 48);
        let m = g.usize_in(1, 6);
        let e = g.usize_in(2, 8);
        let l = g.usize_in(1, n);
        let mut rng = g.rng().fork();
        let cb = pq::Codebooks::random(m, e, 4, &mut rng);
        let x = rng.normal_vec(n * cb.d());
        let y = rng.normal_vec(n * cb.d());
        let cq = pq::quantize(&y, &cb);
        let ck = pq::quantize(&x, &cb);
        let bucket = topl::select(&cq, &ck, l, false);
        let tables = naive_pq::ScoreTables::build(&cb);
        let naive = naive_pq::select(&cq, &ck, &tables, l, false);
        prop_assert(bucket.l == l && naive.l == l, "arity")?;
        prop_assert(bucket.n == n && naive.n == n, "rows")?;
        for b_row in bucket.rows() {
            let uniq: std::collections::HashSet<_> = b_row.iter().collect();
            prop_assert(uniq.len() == l, "bucket dup")?;
        }
        // ranking invariant for bucket sort
        for (qi, row) in bucket.rows().enumerate() {
            let score =
                |j: u32| pq::match_score(cq.row(qi), ck.row(j as usize)) as i64;
            for w in row.windows(2) {
                let (a, b) = (score(w[0]), score(w[1]));
                prop_assert(
                    a > b || (a == b && w[0] < w[1]),
                    format!("row {qi}: order violated {w:?} ({a} vs {b})"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn full_sparse_mha_pipeline_error_shrinks_with_l() {
    check(10, |g| {
        let n = 64usize;
        let d = 32usize;
        let mut rng = g.rng().fork();
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let noise = Matrix::randn(n, d, 0.5, &mut rng);
        let q = Matrix::from_vec(
            n,
            d,
            k.data.iter().zip(&noise.data).map(|(a, b)| 2.0 * a + b).collect(),
        );
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let mut cb = pq::Codebooks::random(4, 8, 8, &mut rng);
        for _ in 0..4 {
            pq::codebook_update(&k.data, &mut cb, 1.0);
        }
        let e_small = attention::sparse_vs_dense_error(&q, &k, &v, &cb, n / 8);
        let e_full = attention::sparse_vs_dense_error(&q, &k, &v, &cb, n);
        prop_assert(e_full < 1e-4, format!("L=n not exact: {e_full}"))?;
        prop_assert(
            e_full <= e_small + 1e-5,
            format!("error not monotone: {e_full} vs {e_small}"),
        )
    });
}

#[test]
fn csr_attention_row_stochastic() {
    check(25, |g| {
        let n = g.usize_in(2, 32);
        let d = g.usize_in(1, 16);
        let l = g.usize_in(1, n);
        let mut rng = g.rng().fork();
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let idx: Vec<Vec<u32>> = (0..n)
            .map(|_| {
                let mut ids: Vec<u32> = (0..n as u32).collect();
                rng.shuffle(&mut ids);
                ids.truncate(l);
                ids
            })
            .collect();
        let mut a = Csr::from_rows(&idx, n);
        a.validate().map_err(|e| e.to_string())?;
        a.sddmm(&q, &k);
        a.softmax_rows();
        for r in 0..n {
            let sum: f32 = a.values[a.row_range(r)].iter().sum();
            prop_assert((sum - 1.0).abs() < 1e-4, format!("row {r} sum {sum}"))?;
            prop_assert(
                a.values[a.row_range(r)].iter().all(|&w| (0.0..=1.0001).contains(&w)),
                "weight out of [0,1]",
            )?;
        }
        Ok(())
    });
}

#[test]
fn routed_ffn_conservation_and_equivalence() {
    // Every (token, active-block) pair is computed exactly once: BSpMV
    // output equals the dense gated reference, and zeroing a token's gate
    // removes exactly its contribution.
    check(20, |g| {
        let nt = g.usize_in(2, 24);
        let d = g.usize_in(2, 8);
        let gg = *g.pick(&[2usize, 4]);
        let dg = g.usize_in(1, 4);
        let ga = g.usize_in(1, gg);
        let mut rng = g.rng().fork();
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, gg * dg, 0.3, &mut rng);
        let wo = Matrix::randn(gg * dg, d, 0.3, &mut rng);
        let scores = Matrix::randn(nt, gg, 1.0, &mut rng);
        let mut routing = bspmv::route(&scores, ga);
        let y = bspmv::routed_ffn(&x, &wi, &wo, &routing);
        let want = bspmv::dense_gated_ffn(&x, &wi, &wo, &routing);
        prop_assert(
            y.max_abs_diff(&want) < 1e-4,
            format!("diff {}", y.max_abs_diff(&want)),
        )?;
        // Zero token 0's gates -> its output row becomes exactly zero.
        for gi in 0..gg {
            routing.gate[0][gi] = 0.0;
        }
        let y2 = bspmv::routed_ffn(&x, &wi, &wo, &routing);
        prop_assert(
            y2.row(0).iter().all(|&v| v == 0.0),
            "gated-out token still contributed",
        )?;
        // Other rows unchanged.
        for r in 1..nt {
            for c in 0..d {
                if (y.at(r, c) - y2.at(r, c)).abs() > 1e-5 {
                    return Err(format!("row {r} changed"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn pq_error_never_increases_under_updates() {
    check(15, |g| {
        let mut rng = g.rng().fork();
        let m = g.usize_in(1, 4);
        let e = g.usize_in(2, 8);
        let mut cb = pq::Codebooks::random(m, e, 4, &mut rng);
        let x = rng.normal_vec(96 * cb.d());
        let mut prev = pq::quantize_error(&x, &cb);
        for _ in 0..4 {
            pq::codebook_update(&x, &mut cb, 1.0);
            let now = pq::quantize_error(&x, &cb);
            prop_assert(
                now <= prev + 1e-5,
                format!("error increased {prev} -> {now}"),
            )?;
            prev = now;
        }
        Ok(())
    });
}
