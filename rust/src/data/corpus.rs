//! Synthetic language corpus: Zipf unigrams + Markov bigram structure.
//!
//! Wikitext-103 substitute.  Token frequencies follow a Zipf law (as in
//! natural language) and each token's successor distribution concentrates
//! on a small per-token set, giving the corpus real bigram structure a
//! Transformer can learn — so the loss curve and the PPL-vs-sparsity sweep
//! (Fig. 10) are meaningful, not flat noise.

use crate::util::rng::Rng;

/// Generator over a fixed vocabulary.
pub struct SyntheticCorpus {
    vocab: usize,
    /// Zipf sampling table: cumulative weights.
    cum: Vec<f64>,
    /// Per-token successor candidates (bigram structure).
    successors: Vec<Vec<u32>>,
    /// Probability of following the bigram model vs. unigram resample.
    bigram_p: f64,
    rng: Rng,
}

impl SyntheticCorpus {
    /// `branch`: successor-set size per token (smaller = more learnable);
    /// `bigram_p`: fraction of transitions that follow the bigram table.
    pub fn new(vocab: usize, branch: usize, bigram_p: f64, seed: u64) -> Self {
        assert!(vocab >= 4 && branch >= 1);
        let mut rng = Rng::new(seed);
        // Zipf weights w_i ~ 1 / (i+1)^s with s = 1.1.
        let mut cum = Vec::with_capacity(vocab);
        let mut acc = 0.0f64;
        for i in 0..vocab {
            acc += 1.0 / ((i + 1) as f64).powf(1.1);
            cum.push(acc);
        }
        // Random successor sets; token ids permuted so ranks are scattered.
        let successors = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.below(vocab) as u32).collect()) // det: cast-bounded
            .collect();
        SyntheticCorpus { vocab, cum, successors, bigram_p, rng }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    fn sample_unigram(&mut self) -> u32 {
        let total = *self.cum.last().unwrap();
        let x = self.rng.f64() * total;
        // binary search the cumulative table
        match self.cum.binary_search_by(|c| c.total_cmp(&x)) {
            Ok(i) | Err(i) => i.min(self.vocab - 1) as u32, // det: cast-bounded (< vocab)
        }
    }

    /// Generate one sequence of `len` tokens.
    pub fn sequence(&mut self, len: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(len);
        let mut prev = self.sample_unigram();
        out.push(prev);
        for _ in 1..len {
            let next = if self.rng.f64() < self.bigram_p {
                let succ = &self.successors[prev as usize];
                succ[self.rng.below(succ.len())]
            } else {
                self.sample_unigram()
            };
            out.push(next);
            prev = next;
        }
        out
    }

    /// (tokens, targets) pair for next-token prediction: targets are
    /// tokens shifted left, with a fresh sample at the boundary.
    pub fn lm_pair(&mut self, len: usize) -> (Vec<u32>, Vec<u32>) {
        let seq = self.sequence(len + 1);
        (seq[..len].to_vec(), seq[1..].to_vec())
    }

    /// Entropy upper bound of the bigram process (nats) — the floor a
    /// perfect model's loss approaches; useful for sanity-checking runs.
    pub fn entropy_bound(&self, branch: usize) -> f64 {
        // Bigram steps contribute <= ln(branch); unigram steps <= ln(V).
        self.bigram_p * (branch.max(2) as f64).ln()
            + (1.0 - self.bigram_p) * (self.vocab as f64).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let mut c = SyntheticCorpus::new(1000, 4, 0.8, 1);
        let seq = c.sequence(4096);
        assert!(seq.iter().all(|&t| (t as usize) < 1000));
        assert_eq!(seq.len(), 4096);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SyntheticCorpus::new(500, 4, 0.8, 7);
        let mut b = SyntheticCorpus::new(500, 4, 0.8, 7);
        assert_eq!(a.sequence(256), b.sequence(256));
    }

    #[test]
    fn zipf_head_is_frequent() {
        let mut c = SyntheticCorpus::new(1000, 4, 0.0, 2); // pure unigram
        let seq = c.sequence(20_000);
        let head = seq.iter().filter(|&&t| t < 10).count() as f64 / seq.len() as f64;
        let tail = seq.iter().filter(|&&t| t >= 500).count() as f64 / seq.len() as f64;
        assert!(head > 0.2, "head mass {head}");
        assert!(tail < head, "tail {tail} head {head}");
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // With bigram_p=1, successors come from a size-4 set: the empirical
        // successor entropy must be far below ln(V).
        let mut c = SyntheticCorpus::new(256, 4, 1.0, 3);
        let seq = c.sequence(30_000);
        let mut succ_sets: Vec<std::collections::BTreeSet<u32>> =
            vec![std::collections::BTreeSet::new(); 256];
        for w in seq.windows(2) {
            succ_sets[w[0] as usize].insert(w[1]);
        }
        let avg: f64 = succ_sets
            .iter()
            .filter(|s| !s.is_empty())
            .map(|s| s.len() as f64)
            .sum::<f64>()
            / succ_sets.iter().filter(|s| !s.is_empty()).count() as f64;
        assert!(avg <= 4.01, "avg successors {avg}");
    }

    #[test]
    fn lm_pair_is_shifted() {
        let mut c = SyntheticCorpus::new(128, 4, 0.8, 4);
        let (x, y) = c.lm_pair(64);
        assert_eq!(x.len(), 64);
        assert_eq!(y.len(), 64);
        assert_eq!(&x[1..], &y[..63]);
    }
}
