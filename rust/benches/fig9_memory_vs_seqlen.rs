//! Paper Fig. 9: peak memory vs sequence length (OPT-2048, batch 16).
//!
//! Pure memory-model regeneration: dense attention grows quadratically
//! with n; SPT's gap over LoRA widens with n ("more substantial memory
//! savings for longer sequences as MHA becomes more predominant").
//! Also verifies batch size has minimal impact on the *relative* saving
//! (paper: "MHA operates along the sequence dimension").

mod common;

use spt::config::{presets, Mode};
use spt::memmodel::{block_peak, BlockWorkload};
use spt::metrics::Table;
use spt::util::fmt_bytes;

fn main() {
    let cfg = presets::block("opt-2048").expect("config");
    let mut table = Table::new(
        "Fig. 9 — peak block memory vs sequence length (OPT-2048, batch 16)",
        &["Seq", "Full", "LoRA", "SPT", "SPT/LoRA"],
    );
    for seq in [128usize, 256, 512, 768, 1024, 1536, 2048] {
        let wl = BlockWorkload { batch: 16, seq };
        let peaks: Vec<u64> = Mode::ALL
            .iter()
            .map(|&m| block_peak(&cfg, m, &wl).peak_bytes())
            .collect();
        table.row(&[
            seq.to_string(),
            fmt_bytes(peaks[0]),
            fmt_bytes(peaks[1]),
            fmt_bytes(peaks[2]),
            format!("{:.0}%", 100.0 * peaks[2] as f64 / peaks[1] as f64),
        ]);
    }
    common::emit("fig9_memory_vs_seqlen", &table);

    // Batch-size invariance of the relative saving.
    let mut t2 = Table::new(
        "Fig. 9 (aux) — SPT/LoRA memory ratio vs batch size (seq 512)",
        &["Batch", "SPT/LoRA"],
    );
    for batch in [1usize, 4, 16, 64] {
        let wl = BlockWorkload { batch, seq: 512 };
        let lora = block_peak(&cfg, Mode::Lora, &wl).peak_bytes();
        let spt = block_peak(&cfg, Mode::Spt, &wl).peak_bytes();
        t2.row(&[
            batch.to_string(),
            format!("{:.1}%", 100.0 * spt as f64 / lora as f64),
        ]);
    }
    common::emit("fig9_batch_invariance", &t2);
}
