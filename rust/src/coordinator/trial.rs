//! Sparsity trial manager (paper §3): "To help users determine the
//! strength of sparsification, SPT allows users to conduct short training
//! trials on some sample data."
//!
//! Runs short fine-tuning trials across the tuning modes on any
//! [`Backend`] and ranks them by a quality/efficiency objective,
//! regenerating the Fig. 10 sweep along the way.

use anyhow::Result;

use super::backend::Backend;
use super::trainer::{Trainer, TrainerOptions};
use crate::config::{Mode, RunConfig};
use crate::metrics::Table;

/// One trial outcome.
#[derive(Debug, Clone)]
pub struct TrialResult {
    pub label: String,
    pub mode: Mode,
    pub final_loss: f32,
    pub ppl: f32,
    pub secs_per_step: f64,
    pub tokens_per_sec: f64,
}

/// Sweep over the tuning modes the backend can run for one model
/// (full/lora/spt); per paper Fig. 10 this is how sparsity strength is
/// chosen before a long run.
pub struct TrialManager<'b, B: Backend> {
    backend: &'b B,
    base: RunConfig,
    pub steps_per_trial: usize,
}

impl<'b, B: Backend> TrialManager<'b, B> {
    pub fn new(backend: &'b B, base: RunConfig, steps_per_trial: usize) -> Self {
        TrialManager { backend, base, steps_per_trial }
    }

    /// Run one trial in a given mode.
    pub fn run_trial(&self, mode: Mode) -> Result<TrialResult> {
        let mut rc = self.base.clone();
        rc.mode = mode;
        rc.steps = self.steps_per_trial;
        rc.eval_every = self.steps_per_trial; // single eval at the end
        let mut trainer = Trainer::new(self.backend, rc, TrainerOptions::default());
        let report = trainer.train()?;
        Ok(TrialResult {
            label: format!("{}-{}", report.model, mode.as_str()),
            mode,
            final_loss: *report.losses.last().unwrap_or(&f32::NAN),
            ppl: report.final_ppl(),
            secs_per_step: report.total_secs / report.steps.max(1) as f64,
            tokens_per_sec: report.tokens_per_sec,
        })
    }

    /// Run trials for all modes and render a comparison table.
    pub fn compare_modes(&self) -> Result<(Vec<TrialResult>, Table)> {
        let mut results = Vec::new();
        for mode in Mode::ALL {
            if !self.backend.has_mode(&self.base, mode) {
                continue;
            }
            results.push(self.run_trial(mode)?);
        }
        let mut table = Table::new(
            &format!(
                "Sparsity trials — {} ({} backend)",
                self.base.model,
                self.backend.name()
            ),
            &["System", "Final loss", "PPL", "s/step", "tokens/s"],
        );
        for r in &results {
            table.row(&[
                r.label.clone(),
                format!("{:.3}", r.final_loss),
                format!("{:.2}", r.ppl),
                format!("{:.3}", r.secs_per_step),
                format!("{:.0}", r.tokens_per_sec),
            ]);
        }
        Ok((results, table))
    }
}

/// Recommend a mode: fastest among those within `tolerance` relative
/// PPL of the best (the paper's efficiency/quality trade-off knob).
pub fn recommend(results: &[TrialResult], tolerance: f32) -> Option<&TrialResult> {
    let best_ppl = results
        .iter()
        .map(|r| r.ppl)
        .fold(f32::INFINITY, f32::min);
    results
        .iter()
        .filter(|r| r.ppl <= best_ppl * (1.0 + tolerance))
        .min_by(|a, b| a.secs_per_step.total_cmp(&b.secs_per_step))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(label: &str, ppl: f32, sps: f64) -> TrialResult {
        TrialResult {
            label: label.into(),
            mode: Mode::Spt,
            final_loss: ppl.ln(),
            ppl,
            secs_per_step: sps,
            tokens_per_sec: 1.0 / sps,
        }
    }

    #[test]
    fn recommend_prefers_fast_within_tolerance() {
        let results = vec![
            tr("full", 10.0, 1.0),
            tr("lora", 10.1, 0.8),
            tr("spt", 10.5, 0.5),
        ];
        // 10% tolerance: spt (10.5 <= 11.0) and fastest.
        let r = recommend(&results, 0.10).unwrap();
        assert_eq!(r.label, "spt");
        // 1% tolerance: only full/lora qualify; lora is faster.
        let r = recommend(&results, 0.01).unwrap();
        assert_eq!(r.label, "lora");
    }

    #[test]
    fn recommend_empty_is_none() {
        assert!(recommend(&[], 0.1).is_none());
    }
}
