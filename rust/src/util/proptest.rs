//! Tiny property-testing harness (no `proptest` crate offline).
//!
//! A property is a closure over a [`Gen`]; the harness runs it for N random
//! cases with distinct seeds and, on failure, retries with the failing seed
//! reported so the case is reproducible:
//!
//! ```ignore
//! check(100, |g| {
//!     let n = g.usize_in(1, 64);
//!     let xs = g.vec_f32(n);
//!     prop_assert(xs.len() == n, "length preserved")
//! });
//! ```

use super::rng::Rng;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range(lo, hi + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        self.rng.normal_vec(n)
    }

    pub fn vec_usize(&mut self, n: usize, below: usize) -> Vec<usize> {
        (0..n).map(|_| self.rng.below(below)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Result of one property case.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `prop`; panics with the failing seed.
pub fn check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    // Base seed is stable per test binary run unless overridden, so CI is
    // reproducible; set SPT_PROPTEST_SEED to explore.
    let base = std::env::var("SPT_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with SPT_PROPTEST_SEED={base} (case index {case})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |g| {
            let n = g.usize_in(1, 32);
            prop_assert(g.vec_f32(n).len() == n, "len")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        check(10, |g| {
            prop_assert(g.usize_in(0, 10) > 100, "impossible")
        });
    }

    #[test]
    fn generator_ranges() {
        check(100, |g| {
            let x = g.i64_in(-5, 5);
            prop_assert((-5..=5).contains(&x), format!("{x} out of range"))?;
            let f = g.f32_in(1.0, 2.0);
            prop_assert((1.0..=2.0).contains(&f), format!("{f} out of range"))
        });
    }
}
