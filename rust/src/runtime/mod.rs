//! L3 runtime: host tensors + artifact manifests (always available), and
//! the PJRT execution engine (behind the `xla` feature).
//!
//! * [`manifest`] — parsed `artifacts/manifest.json` (signatures + metadata)
//! * [`tensor`]   — host tensors; the working representation shared by
//!   every training backend (literal marshalling is `xla`-gated)
//! * [`engine`]   — compile cache + execution (`xla` feature)
//! * [`goldens`]  — numeric round-trip validation vs python (`xla`)
//! * `xla`        — compile-time stub for the PJRT bindings crate, so
//!   `--features xla` builds without the external dependency
//!
//! Since the native-backend refactor, `tensor` and `manifest` compile in
//! the default build: [`HostTensor`] is the parameter/optimizer leaf
//! type of [`crate::coordinator::TrainState`], which the engine-free
//! [`crate::coordinator::NativeBackend`] trains directly.

#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod goldens;
pub mod manifest;
pub mod tensor;
// Public because `Engine` / `HostTensor` expose these types in their
// signatures (buffers, literals) exactly as they would with the real
// bindings crate.
#[cfg(feature = "xla")]
pub mod xla;

#[cfg(feature = "xla")]
pub use engine::{DeviceState, Engine, ExecStats};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
pub use tensor::HostTensor;
