//! Decode caches: the dense per-sequence [`DecodeCache`] (the solo
//! [`super::Session`] reference layout) and the paged [`PagePool`] that
//! backs the multi-tenant serve driver.
//!
//! **Dense cache.** Keys and values append row by row as decode
//! advances; codes append through [`pq::quantize_append`], so the
//! cached code matrix is always bit-identical to a fresh quantization
//! of the cached keys — which is exactly what the training forward's
//! top-L selection consumes.
//!
//! **Paged pool.** Fixed-size pages of `page_tokens` positions hold the
//! K/V rows and PQ codes of *all* layers and heads for those positions,
//! carved out of three pre-allocated slabs.  A request owns a
//! [`PageTable`] (page ids in position order); pages are refcounted so
//! requests with a common prompt prefix can map the same physical
//! pages.  Prefix sharing is keyed on `(l_sess, parent_page,
//! token_chunk)` in a chunk trie: a page is only ever reused when the
//! session L *and* every prompt token it covers match, which (with the
//! per-row `l_eff = min(l, pos+1)` clamp inside the decode kernel)
//! makes shared bytes bit-identical to privately recomputed ones.
//! Writes require `refcount == 1`; [`PagePool::cow`] detaches a shared
//! page first.  All bookkeeping uses `BTreeMap`/`BTreeSet` and
//! smallest-id-first allocation, so page placement is deterministic.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Result};

use crate::sparse::pq::{self, Codebooks};
use crate::sparse::{Codes, Matrix};

/// One layer's cached decode state.
pub struct LayerCache {
    /// Per-head cached keys, `[len, d_head]` each.
    pub k: Vec<Matrix>,
    /// Per-head cached values, `[len, d_head]` each.
    pub v: Vec<Matrix>,
    /// spt only: per-head PQ codes of the cached keys (`[len, M]`).
    pub codes: Option<Vec<Codes>>,
}

/// Per-sequence decode cache: one [`LayerCache`] per transformer layer.
pub struct DecodeCache {
    pub layers: Vec<LayerCache>,
}

impl DecodeCache {
    /// An empty cache for an `n_layers`-deep model.  `pq_m` is `Some`
    /// (the per-head subspace count) in spt mode, `None` otherwise.
    pub fn new(n_layers: usize, heads: usize, d_head: usize, pq_m: Option<usize>) -> Self {
        let layers = (0..n_layers)
            .map(|_| LayerCache {
                k: (0..heads).map(|_| Matrix::zeros(0, d_head)).collect(),
                v: (0..heads).map(|_| Matrix::zeros(0, d_head)).collect(),
                codes: pq_m.map(|m| (0..heads).map(|_| Codes::zeros(0, m)).collect()),
            })
            .collect();
        DecodeCache { layers }
    }

    /// Cached positions (every layer and head stays in lockstep).
    pub fn len(&self) -> usize {
        self.layers
            .first()
            .and_then(|lc| lc.k.first())
            .map(|m| m.rows)
            .unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one position's K/V rows (`[heads * d_head]` concatenated
    /// head-major, the projection row layout) to layer `li`, quantizing
    /// the new key against `cbs` when this cache carries codes.
    pub fn append(
        &mut self,
        li: usize,
        k_row: &[f32],
        v_row: &[f32],
        cbs: Option<&[Codebooks]>,
    ) -> Result<()> {
        let lc = &mut self.layers[li];
        let heads = lc.k.len();
        let dh = lc.k[0].cols;
        if k_row.len() != heads * dh || v_row.len() != heads * dh {
            bail!(
                "append row has {} values, cache wants {} heads x {}",
                k_row.len(),
                heads,
                dh
            );
        }
        if lc.codes.is_some() && cbs.is_none() {
            bail!("cache carries PQ codes but no codebooks were supplied");
        }
        for h in 0..heads {
            let seg = h * dh..(h + 1) * dh;
            lc.k[h].rows += 1;
            lc.k[h].data.extend_from_slice(&k_row[seg.clone()]);
            lc.v[h].rows += 1;
            lc.v[h].data.extend_from_slice(&v_row[seg.clone()]);
            if let (Some(codes), Some(cbs)) = (&mut lc.codes, cbs) {
                pq::quantize_append(&k_row[seg], &cbs[h], &mut codes[h]);
            }
        }
        Ok(())
    }

    /// Measured bytes held by this cache (K/V floats + code bytes) —
    /// the runtime twin of the analytic `memmodel::decode` accounting.
    pub fn bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|lc| {
                let kv: usize = lc.k.iter().chain(&lc.v).map(Matrix::bytes).sum();
                let codes: usize = lc
                    .codes
                    .as_ref()
                    .map(|cs| cs.iter().map(Codes::bytes).sum())
                    .unwrap_or(0);
                kv + codes
            })
            .sum()
    }
}

/// A request's view into the pool: physical page ids in position order.
/// Position `p` lives in `pages[p / page_tokens]` at slot
/// `p % page_tokens`.
#[derive(Default)]
pub struct PageTable {
    pub pages: Vec<usize>,
}

/// Sentinel parent for the first page of a prefix chain.
const NO_PARENT: usize = usize::MAX;

/// Prefix-trie key: a page is shareable only between requests whose
/// session L matches, whose earlier prompt pages are the *same physical
/// pages*, and whose tokens over this page's span are identical.
type ShareKey = (usize, usize, Vec<i32>);

/// Fixed-size paged KV+code storage shared by every slot of one serve
/// driver.  See the module docs for the layout and sharing contract.
pub struct PagePool {
    page_tokens: usize,
    n_layers: usize,
    heads: usize,
    d_head: usize,
    pq_m: Option<usize>,
    /// K slab: page-major, then `[layer][head][slot][d_head]`.
    k: Vec<f32>,
    /// V slab, same layout as `k`.
    v: Vec<f32>,
    /// Code slab (empty unless `pq_m`): page-major, then
    /// `[layer][head][slot][m]`.
    codes: Vec<u8>,
    refcount: Vec<usize>,
    /// Free page ids; smallest-first pop keeps placement deterministic.
    free: BTreeSet<usize>,
    sharing: bool,
    share_index: BTreeMap<ShareKey, usize>,
    /// Reverse map for unregistration when a page's refcount hits 0.
    share_key: Vec<Option<ShareKey>>,
    shared_page_hits: usize,
    cow_copies: usize,
}

impl PagePool {
    pub fn new(
        pages: usize,
        page_tokens: usize,
        n_layers: usize,
        heads: usize,
        d_head: usize,
        pq_m: Option<usize>,
        sharing: bool,
    ) -> Result<Self> {
        if pages == 0 || page_tokens == 0 {
            bail!("page pool needs >= 1 page of >= 1 token (got {pages} x {page_tokens})");
        }
        if n_layers == 0 || heads == 0 || d_head == 0 {
            bail!("degenerate pool shape: {n_layers} layers x {heads} heads x {d_head}");
        }
        let kv_len = pages * n_layers * heads * page_tokens * d_head;
        let code_len = pq_m.map_or(0, |m| pages * n_layers * heads * page_tokens * m);
        Ok(PagePool {
            page_tokens,
            n_layers,
            heads,
            d_head,
            pq_m,
            k: vec![0.0; kv_len],
            v: vec![0.0; kv_len],
            codes: vec![0; code_len],
            refcount: vec![0; pages],
            free: (0..pages).collect(),
            sharing,
            share_index: BTreeMap::new(),
            share_key: (0..pages).map(|_| None).collect(),
            shared_page_hits: 0,
            cow_copies: 0,
        })
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Total pages in the pool.
    pub fn pages(&self) -> usize {
        self.refcount.len()
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.pages() - self.free_pages()
    }

    /// Distinct prefix-trie hits so far (each one is `page_tokens`
    /// prompt positions some request did not have to recompute).
    pub fn shared_page_hits(&self) -> usize {
        self.shared_page_hits
    }

    pub fn cow_copies(&self) -> usize {
        self.cow_copies
    }

    /// Bytes of one page across all layers/heads: K + V floats plus
    /// code bytes.  The allocation granule `memmodel::decode_page_bytes`
    /// models analytically.
    pub fn bytes_per_page(&self) -> usize {
        let rows = self.n_layers * self.heads * self.page_tokens;
        rows * self.d_head * 2 * 4 + rows * self.pq_m.unwrap_or(0)
    }

    fn kv_offset(&self, page: usize, li: usize, h: usize) -> usize {
        (((page * self.n_layers) + li) * self.heads + h) * self.page_tokens * self.d_head
    }

    fn code_offset(&self, page: usize, li: usize, h: usize, m: usize) -> usize {
        (((page * self.n_layers) + li) * self.heads + h) * self.page_tokens * m
    }

    /// Allocate a fresh page (refcount 1), smallest free id first.
    /// `None` when the pool is exhausted — the caller's admission
    /// accounting is supposed to make that unreachable mid-flight.
    pub fn alloc(&mut self) -> Option<usize> {
        let page = self.free.pop_first()?;
        self.refcount[page] = 1;
        page.into()
    }

    /// Take one more reference on an already-live page.
    pub fn retain(&mut self, page: usize) {
        debug_assert!(self.refcount[page] > 0, "retain of a free page");
        self.refcount[page] += 1;
    }

    /// Drop one reference; at zero the page leaves the prefix trie and
    /// returns to the free list.
    pub fn release(&mut self, page: usize) {
        debug_assert!(self.refcount[page] > 0, "release of a free page");
        self.refcount[page] -= 1;
        if self.refcount[page] == 0 {
            if let Some(key) = self.share_key[page].take() {
                self.share_index.remove(&key);
            }
            self.free.insert(page);
        }
    }

    pub fn refcount(&self, page: usize) -> usize {
        self.refcount[page]
    }

    /// Write position `pos`'s K/V rows (`[heads * d_head]` head-major,
    /// the projection row layout) for layer `li` through `table`,
    /// quantizing the key when the pool carries codes.  The page must
    /// be privately owned — shared pages take [`PagePool::cow`] first.
    pub fn write_row(
        &mut self,
        table: &PageTable,
        pos: usize,
        li: usize,
        k_row: &[f32],
        v_row: &[f32],
        cbs: Option<&[Codebooks]>,
    ) -> Result<()> {
        let (heads, dh) = (self.heads, self.d_head);
        if k_row.len() != heads * dh || v_row.len() != heads * dh {
            bail!("write row has {} values, pool wants {heads} heads x {dh}", k_row.len());
        }
        if self.pq_m.is_some() && cbs.is_none() {
            bail!("pool carries PQ codes but no codebooks were supplied");
        }
        let Some(&page) = table.pages.get(pos / self.page_tokens) else {
            bail!("position {pos} beyond the page table ({} pages mapped)", table.pages.len());
        };
        if self.refcount[page] != 1 {
            bail!(
                "write to page {page} with refcount {} (copy-on-write must detach it first)",
                self.refcount[page]
            );
        }
        let slot = pos % self.page_tokens;
        for h in 0..heads {
            let seg = h * dh..(h + 1) * dh;
            let base = self.kv_offset(page, li, h) + slot * dh;
            self.k[base..base + dh].copy_from_slice(&k_row[seg.clone()]);
            self.v[base..base + dh].copy_from_slice(&v_row[seg.clone()]);
            if let (Some(m), Some(cbs)) = (self.pq_m, cbs) {
                let cb = self.code_offset(page, li, h, m) + slot * m;
                pq::quantize_row(&k_row[seg], &cbs[h], &mut self.codes[cb..cb + m]);
            }
        }
        Ok(())
    }

    /// Detach `page` for writing: shared pages are byte-copied into a
    /// fresh page (old reference dropped), private pages pass through.
    /// The copy is never trie-registered — the original stays canonical.
    pub fn cow(&mut self, page: usize) -> Result<usize> {
        if self.refcount[page] <= 1 {
            return Ok(page);
        }
        let Some(fresh) = self.alloc() else {
            bail!("page pool exhausted during copy-on-write of page {page}");
        };
        let kv = self.n_layers * self.heads * self.page_tokens * self.d_head;
        self.k.copy_within(page * kv..(page + 1) * kv, fresh * kv);
        self.v.copy_within(page * kv..(page + 1) * kv, fresh * kv);
        if let Some(m) = self.pq_m {
            let cl = self.n_layers * self.heads * self.page_tokens * m;
            self.codes.copy_within(page * cl..(page + 1) * cl, fresh * cl);
        }
        self.cow_copies += 1;
        self.release(page);
        Ok(fresh)
    }

    /// How many leading prompt pages of `prompt` are reusable at all:
    /// fully covered by the prompt *and* strictly before the page
    /// holding the last prompt position (that page is always computed
    /// fresh, so its logits — and a write target — exist; this also
    /// keeps every shared page read-only by construction).
    pub fn reusable_prompt_pages(&self, prompt_len: usize) -> usize {
        (prompt_len / self.page_tokens).min(prompt_len.saturating_sub(1) / self.page_tokens)
    }

    /// Walk the prefix trie for `(l_sess, prompt)` and retain every
    /// page hit.  Returns the matched chain (a prefix of the prompt's
    /// reusable pages); the caller owns one reference on each.
    pub fn acquire_chain(&mut self, l_sess: usize, prompt: &[i32]) -> Vec<usize> {
        let mut pages = Vec::new();
        if !self.sharing {
            return pages;
        }
        let pt = self.page_tokens;
        let mut parent = NO_PARENT;
        for kx in 0..self.reusable_prompt_pages(prompt.len()) {
            let key = (l_sess, parent, prompt[kx * pt..(kx + 1) * pt].to_vec());
            match self.share_index.get(&key) {
                Some(&pg) => {
                    self.refcount[pg] += 1;
                    self.shared_page_hits += 1;
                    parent = pg;
                    pages.push(pg);
                }
                None => break,
            }
        }
        pages
    }

    /// Register this request's computed prompt pages (the first
    /// `covered` positions are valid) into the prefix trie.  First
    /// registration wins; later walkers follow the canonical chain, so
    /// calling this after every prefill chunk is idempotent.
    pub fn register_chain(&mut self, l_sess: usize, prompt: &[i32], table: &PageTable, covered: usize) {
        if !self.sharing {
            return;
        }
        let pt = self.page_tokens;
        let limit = self.reusable_prompt_pages(prompt.len()).min(covered / pt);
        let mut parent = NO_PARENT;
        for kx in 0..limit.min(table.pages.len()) {
            let key = (l_sess, parent, prompt[kx * pt..(kx + 1) * pt].to_vec());
            match self.share_index.get(&key) {
                Some(&pg) => parent = pg,
                None => {
                    let page = table.pages[kx];
                    self.share_index.insert(key.clone(), page);
                    self.share_key[page] = Some(key);
                    parent = page;
                }
            }
        }
    }

    /// Gather the first `n_rows` cached positions of `(li, h)` into
    /// contiguous per-row scratch (`[n_rows, d_head]` K/V and, when
    /// requested, `[n_rows, m]` codes), page-sized block copies at a
    /// time.  The scratch buffers are fully overwritten, so the decode
    /// kernels see exactly the dense cache layout.
    pub fn gather(
        &self,
        table: &PageTable,
        li: usize,
        h: usize,
        n_rows: usize,
        gk: &mut Matrix,
        gv: &mut Matrix,
        gc: Option<&mut Codes>,
    ) {
        let (pt, dh) = (self.page_tokens, self.d_head);
        gk.rows = n_rows;
        gk.cols = dh;
        gk.data.clear();
        gv.rows = n_rows;
        gv.cols = dh;
        gv.data.clear();
        let mut done = 0;
        while done < n_rows {
            let take = (n_rows - done).min(pt);
            let base = self.kv_offset(table.pages[done / pt], li, h);
            gk.data.extend_from_slice(&self.k[base..base + take * dh]);
            gv.data.extend_from_slice(&self.v[base..base + take * dh]);
            done += take;
        }
        if let Some(gc) = gc {
            let m = self.pq_m.expect("code gather on a codeless pool");
            gc.n = n_rows;
            gc.m = m;
            gc.data.clear();
            let mut done = 0;
            while done < n_rows {
                let take = (n_rows - done).min(pt);
                let base = self.code_offset(table.pages[done / pt], li, h, m);
                gc.data.extend_from_slice(&self.codes[base..base + take * m]);
                done += take;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn append_grows_all_heads_in_lockstep() {
        let mut cache = DecodeCache::new(2, 3, 4, Some(2));
        let mut rng = Rng::new(1);
        let cbs: Vec<Codebooks> =
            (0..3).map(|_| Codebooks::random(2, 4, 2, &mut rng)).collect();
        assert!(cache.is_empty());
        for pos in 0..5 {
            for li in 0..2 {
                let k: Vec<f32> = rng.normal_vec(12);
                let v: Vec<f32> = rng.normal_vec(12);
                cache.append(li, &k, &v, Some(&cbs)).unwrap();
            }
            assert_eq!(cache.len(), pos + 1);
        }
        for lc in &cache.layers {
            for h in 0..3 {
                assert_eq!(lc.k[h].rows, 5);
                assert_eq!(lc.v[h].rows, 5);
                assert_eq!(lc.codes.as_ref().unwrap()[h].n, 5);
            }
        }
        // 2 layers x 3 heads x (2 x 5 x 4 floats) + codes 2x3x(5x2 bytes)
        assert_eq!(cache.bytes(), 2 * 3 * 2 * 5 * 4 * 4 + 2 * 3 * 5 * 2);
    }

    #[test]
    fn append_rejects_wrong_row_width_and_missing_codebooks() {
        let mut cache = DecodeCache::new(1, 2, 4, Some(2));
        assert!(cache.append(0, &[0.0; 4], &[0.0; 8], None).is_err());
        assert!(cache.append(0, &[0.0; 8], &[0.0; 8], None).is_err());
        let mut dense = DecodeCache::new(1, 2, 4, None);
        dense.append(0, &[0.0; 8], &[0.0; 8], None).unwrap();
        assert_eq!(dense.len(), 1);
        assert!(dense.layers[0].codes.is_none());
    }

    fn pool_rows(pool: &PagePool, table: &PageTable, li: usize, h: usize, n: usize) -> Vec<f32> {
        let (mut gk, mut gv) = (Matrix::zeros(0, 0), Matrix::zeros(0, 0));
        pool.gather(table, li, h, n, &mut gk, &mut gv, None);
        assert_eq!(gv.rows, n);
        gk.data
    }

    #[test]
    fn paged_writes_gather_back_identical_to_a_dense_cache() {
        let (layers, heads, dh, m) = (2usize, 3usize, 4usize, 2usize);
        let mut rng = Rng::new(9);
        let cbs: Vec<Codebooks> =
            (0..heads).map(|_| Codebooks::random(m, 16, dh / m, &mut rng)).collect();
        let mut dense = DecodeCache::new(layers, heads, dh, Some(m));
        let mut pool = PagePool::new(4, 3, layers, heads, dh, Some(m), true).unwrap();
        let mut table = PageTable::default();
        // 7 positions span 3 pages of 3 tokens.
        for pos in 0..7 {
            while table.pages.len() * 3 < pos + 1 {
                table.pages.push(pool.alloc().unwrap());
            }
            for li in 0..layers {
                let k: Vec<f32> = rng.normal_vec(heads * dh);
                let v: Vec<f32> = rng.normal_vec(heads * dh);
                dense.append(li, &k, &v, Some(&cbs)).unwrap();
                pool.write_row(&table, pos, li, &k, &v, Some(&cbs)).unwrap();
            }
        }
        assert_eq!(pool.pages_in_use(), 3);
        let (mut gk, mut gv) = (Matrix::zeros(0, 0), Matrix::zeros(0, 0));
        let mut gc = Codes::zeros(0, 0);
        for li in 0..layers {
            for h in 0..heads {
                for n in [1usize, 3, 5, 7] {
                    pool.gather(&table, li, h, n, &mut gk, &mut gv, Some(&mut gc));
                    let lc = &dense.layers[li];
                    assert_eq!(gk.data, lc.k[h].data[..n * dh]);
                    assert_eq!(gv.data, lc.v[h].data[..n * dh]);
                    assert_eq!(gc.data, lc.codes.as_ref().unwrap()[h].data[..n * m]);
                }
            }
        }
    }

    #[test]
    fn alloc_release_recycles_smallest_first_and_tracks_refcounts() {
        let mut pool = PagePool::new(3, 2, 1, 1, 2, None, true).unwrap();
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!((a, b), (0, 1), "smallest free id first");
        assert_eq!(pool.free_pages(), 1);
        pool.retain(a);
        pool.release(a);
        assert_eq!(pool.refcount(a), 1, "retained page survives one release");
        assert_eq!(pool.free_pages(), 1);
        pool.release(a);
        assert_eq!(pool.free_pages(), 2);
        assert_eq!(pool.alloc().unwrap(), 0, "freed page is recycled first");
        let c = pool.alloc().unwrap();
        assert_eq!(c, 2);
        assert!(pool.alloc().is_none(), "exhaustion is an Option, not a panic");
        pool.release(b);
        pool.release(c);
        pool.release(0);
        assert_eq!(pool.free_pages(), 3);
    }

    #[test]
    fn prefix_chain_shares_only_aligned_matching_prefixes() {
        let mut pool = PagePool::new(8, 2, 1, 1, 2, None, true).unwrap();
        let prompt: Vec<i32> = vec![1, 2, 3, 4, 5];
        // 5 tokens at 2/page: pages 0..1 fully covered AND before the
        // last position's page -> 2 reusable pages.
        assert_eq!(pool.reusable_prompt_pages(prompt.len()), 2);
        // A 4-token prompt's last position lands in page 1, so only
        // page 0 is reusable even though page 1 is fully covered.
        assert_eq!(pool.reusable_prompt_pages(4), 1);

        let mut table = PageTable::default();
        for _ in 0..3 {
            table.pages.push(pool.alloc().unwrap());
        }
        pool.register_chain(7, &prompt, &table, 5);
        // Same L, same prompt: both reusable pages hit and are retained.
        let chain = pool.acquire_chain(7, &prompt);
        assert_eq!(chain, table.pages[..2]);
        assert_eq!(pool.refcount(chain[0]), 2);
        assert_eq!(pool.shared_page_hits(), 2);
        // Different session L: no hit (selection widths differ).
        assert!(pool.acquire_chain(9, &prompt).is_empty());
        // Diverging second page: only the first page is shared.
        assert_eq!(pool.acquire_chain(7, &[1, 2, 9, 9, 5]), table.pages[..1]);
        // Diverging first token: nothing shared (chain is rooted).
        assert!(pool.acquire_chain(7, &[9, 2, 3, 4, 5]).is_empty());
        // Releasing the original owner keeps shared pages alive for the
        // borrowers (page 0: the full chain + the diverging-prefix walk).
        for &p in &table.pages {
            pool.release(p);
        }
        assert_eq!(pool.refcount(chain[0]), 2, "borrowers still hold the prefix");
        assert_eq!(pool.refcount(chain[1]), 1, "only the chain holds page 1");
        // A trie entry dies with its page's last reference: drop page 1
        // and the walk stops after page 0.
        pool.release(chain[1]);
        let tail = pool.acquire_chain(7, &prompt);
        assert_eq!(tail, vec![chain[0]], "page 1 left the trie");
        for _ in 0..3 {
            pool.release(chain[0]);
        }
        assert!(pool.acquire_chain(7, &prompt).is_empty(), "fully released chain left the trie");
        assert_eq!(pool.free_pages(), 3);
    }

    #[test]
    fn cow_detaches_shared_pages_bytewise_and_blocks_shared_writes() {
        let mut rng = Rng::new(4);
        let mut pool = PagePool::new(3, 2, 1, 2, 4, None, true).unwrap();
        let mut table = PageTable { pages: vec![pool.alloc().unwrap()] };
        let k: Vec<f32> = rng.normal_vec(8);
        let v: Vec<f32> = rng.normal_vec(8);
        pool.write_row(&table, 0, 0, &k, &v, None).unwrap();
        pool.retain(table.pages[0]);
        // Writing through a shared page is a hard error…
        let err = pool.write_row(&table, 1, 0, &k, &v, None).unwrap_err();
        assert!(err.to_string().contains("copy-on-write"), "{err:#}");
        // …until COW detaches it; the copy carries identical bytes.
        let before = pool_rows(&pool, &table, 0, 1, 1);
        let fresh = pool.cow(table.pages[0]).unwrap();
        assert_ne!(fresh, table.pages[0]);
        assert_eq!(pool.refcount(table.pages[0]), 1, "old reference dropped");
        table.pages[0] = fresh;
        assert_eq!(pool_rows(&pool, &table, 0, 1, 1), before, "COW copied the bytes");
        assert_eq!(pool.cow_copies(), 1);
        pool.write_row(&table, 1, 0, &k, &v, None).unwrap();
        // A private page passes through COW untouched.
        assert_eq!(pool.cow(fresh).unwrap(), fresh);
    }

    #[test]
    fn pool_validates_shapes_and_write_bounds() {
        assert!(PagePool::new(0, 16, 1, 1, 4, None, true).is_err());
        assert!(PagePool::new(4, 0, 1, 1, 4, None, true).is_err());
        let mut pool = PagePool::new(2, 2, 1, 1, 4, Some(2), true).unwrap();
        let table = PageTable { pages: vec![pool.alloc().unwrap()] };
        let err = pool.write_row(&table, 2, 0, &[0.0; 4], &[0.0; 4], None).unwrap_err();
        assert!(err.to_string().contains("beyond the page table"), "{err:#}");
        assert!(pool.write_row(&table, 0, 0, &[0.0; 3], &[0.0; 4], None).is_err());
        // Codes demand codebooks, exactly like the dense cache.
        assert!(pool.write_row(&table, 0, 0, &[0.0; 4], &[0.0; 4], None).is_err());
        // 2-token page over 1 layer x 1 head: 2 slots x d_head 4 x
        // (K+V) floats + 2 slots x m 2 code bytes.
        assert_eq!(pool.bytes_per_page(), 2 * 2 * 4 * 4 + 2 * 2);
    }
}
