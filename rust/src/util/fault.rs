//! Deterministic fault injection for chaos tests.
//!
//! A [`FaultPlan`] is a site-keyed table of "fire on the N-th probe"
//! triggers, parsed from a spec like `ckpt_write_err:1,queue_full:2`
//! (comma-separated `site:arg` pairs — the `SPT_FAULT_PLAN` environment
//! variable uses the same syntax).  Production code threads an
//! `Option<&FaultPlan>` through its I/O seams and probes named sites;
//! with no plan armed every probe is free and nothing changes.
//!
//! Determinism contract: a site fires on its N-th *probe*, and every
//! probe site in this codebase sits on a sequential control path (a
//! checkpoint save attempt, a daemon submission, a listener accept) —
//! never inside a rayon-parallel region — so a given plan injects the
//! same faults at the same points at any pool size.  Recoverable faults
//! (transient write errors, queue-full rejections) must not perturb
//! bit-identical train/decode outputs; `tests/crash_safety.rs` and
//! `tests/daemon_lifecycle.rs` assert exactly that.
//!
//! Known sites (args in parentheses):
//!
//! * `ckpt_write_err` (N) — the N-th checkpoint save attempt fails with
//!   a transient I/O error before writing; the retry layer recovers it.
//! * `ckpt_crash` (N) — the N-th checkpoint save attempt stops mid-write
//!   after [`Self::crash_bytes`] bytes and surfaces a [`Crash`] error:
//!   the moral equivalent of `kill -9` between two `write(2)` calls.
//!   The atomic-rename protocol must leave the previous checkpoint
//!   intact (asserted by the crash-recovery test).
//! * `ckpt_crash_bytes` (B) — parameter site (never fires): how many
//!   bytes a `ckpt_crash` save writes before dying (default 256 —
//!   past the header, mid-tensor for any real state).
//! * `queue_full` (N) — the daemon reports its bounded queue full on the
//!   N-th admission probe regardless of actual occupancy.
//! * `accept_err` (N) — the daemon's N-th listener accept fails with a
//!   transient error (exercises the accept retry/backoff path).
//! * `page_pool_exhausted` (N) — the serve driver's N-th admission probe
//!   reports the KV page pool starved: the request stays queued and is
//!   admitted on a later step (transient; no stream may be perturbed).

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// Marker error for injected crash faults: fatal by design — the retry
/// layer refuses to retry across one (a real crash would not retry
/// either), and test harnesses treat it as the process dying.
#[derive(Debug)]
pub struct Crash {
    pub site: String,
}

impl std::fmt::Display for Crash {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected crash at fault site '{}'", self.site)
    }
}

impl std::error::Error for Crash {}

/// Whether an error chain contains an injected [`Crash`] (fatal: do not
/// retry, unwind as if the process died).
pub fn is_crash(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.downcast_ref::<Crash>().is_some())
}

#[derive(Debug, Default)]
struct SiteState {
    /// Fire on this probe ordinal (1-based); 0 = parameter-only site.
    arg: u64,
    probes: u64,
}

/// A deterministic, site-keyed fault plan.
#[derive(Debug, Default)]
pub struct FaultPlan {
    sites: Mutex<BTreeMap<String, SiteState>>,
}

impl FaultPlan {
    /// An empty plan (no site ever fires).
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse a `site:arg,site:arg` spec.
    pub fn parse(spec: &str) -> Result<Self> {
        let plan = FaultPlan::new();
        {
            let mut sites = plan.sites.lock().expect("fault plan lock");
            for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
                let (site, arg) = part
                    .split_once(':')
                    .with_context(|| format!("fault plan entry '{part}': expected site:arg"))?;
                let arg: u64 = arg
                    .trim()
                    .parse()
                    .with_context(|| format!("fault plan entry '{part}': arg"))?;
                if site.trim().is_empty() {
                    bail!("fault plan entry '{part}': empty site");
                }
                sites.insert(site.trim().to_string(), SiteState { arg, probes: 0 });
            }
        }
        Ok(plan)
    }

    /// Build the plan from `SPT_FAULT_PLAN`, if set (empty/unset = none).
    pub fn from_env() -> Result<Option<Self>> {
        match std::env::var("SPT_FAULT_PLAN") {
            Ok(spec) if !spec.trim().is_empty() => {
                Ok(Some(Self::parse(&spec).context("SPT_FAULT_PLAN")?))
            }
            _ => Ok(None),
        }
    }

    /// Arm (or re-arm) a site programmatically: fire on the `on_probe`-th
    /// [`Self::fire`] call for `site`.  Builder-style for tests.
    pub fn with(self, site: &str, on_probe: u64) -> Self {
        self.sites
            .lock()
            .expect("fault plan lock")
            .insert(site.into(), SiteState { arg: on_probe, probes: 0 });
        self
    }

    /// Probe `site`: record the probe and report whether the fault fires
    /// (exactly once, on the armed ordinal).  Unknown sites never fire.
    pub fn fire(&self, site: &str) -> bool {
        let mut sites = self.sites.lock().expect("fault plan lock");
        match sites.get_mut(site) {
            Some(s) => {
                s.probes += 1;
                s.arg != 0 && s.probes == s.arg
            }
            None => false,
        }
    }

    /// Read a parameter site's value without counting a probe.
    pub fn arg(&self, site: &str) -> Option<u64> {
        self.sites
            .lock()
            .expect("fault plan lock")
            .get(site)
            .map(|s| s.arg)
    }

    /// How many times `site` has been probed (test observability).
    pub fn probes(&self, site: &str) -> u64 {
        self.sites
            .lock()
            .expect("fault plan lock")
            .get(site)
            .map(|s| s.probes)
            .unwrap_or(0)
    }

    /// Byte offset at which a `ckpt_crash` save dies (the
    /// `ckpt_crash_bytes` parameter site; default 256).
    pub fn crash_bytes(&self) -> u64 {
        self.arg("ckpt_crash_bytes").unwrap_or(256)
    }
}

/// Convenience for call sites holding an `Option<&FaultPlan>`.
pub fn fire(plan: Option<&FaultPlan>, site: &str) -> bool {
    plan.is_some_and(|p| p.fire(site))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_exactly_once_on_the_nth_probe() {
        let plan = FaultPlan::parse("io_write:3,queue_full:1").unwrap();
        assert!(!plan.fire("io_write"));
        assert!(!plan.fire("io_write"));
        assert!(plan.fire("io_write"), "third probe fires");
        assert!(!plan.fire("io_write"), "fires once, then disarms");
        assert!(plan.fire("queue_full"));
        assert!(!plan.fire("queue_full"));
        assert!(!plan.fire("unknown_site"));
        assert_eq!(plan.probes("io_write"), 4);
    }

    #[test]
    fn parameter_sites_and_builder() {
        let plan = FaultPlan::new().with("ckpt_crash", 2).with("ckpt_crash_bytes", 100);
        assert_eq!(plan.crash_bytes(), 100);
        assert_eq!(FaultPlan::new().crash_bytes(), 256);
        assert!(!plan.fire("ckpt_crash"));
        assert!(plan.fire("ckpt_crash"));
        // arg() reads do not consume probes.
        assert_eq!(plan.arg("ckpt_crash"), Some(2));
        assert_eq!(plan.probes("ckpt_crash_bytes"), 0);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("no_colon").is_err());
        assert!(FaultPlan::parse("site:notanumber").is_err());
        assert!(FaultPlan::parse(":3").is_err());
        // Empty spec parses to an inert plan.
        let plan = FaultPlan::parse("").unwrap();
        assert!(!plan.fire("anything"));
    }

    #[test]
    fn crash_marker_is_detectable_through_anyhow_chains() {
        let io = std::io::Error::other(Crash { site: "ckpt_crash".into() });
        let err = anyhow::Error::from(io).context("saving checkpoint");
        assert!(is_crash(&err));
        let plain = anyhow::anyhow!("disk full");
        assert!(!is_crash(&plain));
    }
}
