//! Training state: parameter + optimizer leaves, ordered exactly as the
//! AOT train-step artifact expects them.
//!
//! Leaf order contract (from `aot.py` / jax pytree flattening of
//! `(params, opt, tokens, targets)` with `opt = {"m", "step", "v"}`):
//!
//! ```text
//! inputs  = [params x P, m x P, step, v x P, tokens, targets]
//! outputs = [loss, params x P, m x P, step, v x P]
//! ```

use anyhow::{bail, Context, Result};

use crate::runtime::{ArtifactSpec, Engine, HostTensor};

/// Host-side training state for one model+mode.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: HostTensor,
    /// Leaf paths of `params` (from the init artifact), for named lookup.
    pub param_paths: Vec<String>,
}

impl TrainState {
    /// Initialize by executing the `model_init_*` artifact.
    pub fn init(engine: &Engine, init_artifact: &str, seed: i32) -> Result<Self> {
        let spec = engine.spec(init_artifact)?.clone();
        let params = engine.run(init_artifact, &[HostTensor::scalar_i32(seed)])?;
        let m = params
            .iter()
            .map(|p| {
                HostTensor::zeros(&crate::runtime::TensorSpec {
                    shape: p.shape().to_vec(),
                    dtype: p.dtype(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let v = m.clone();
        Ok(TrainState {
            params,
            m,
            v,
            step: HostTensor::scalar_i32(0),
            param_paths: spec.output_paths.clone(),
        })
    }

    pub fn n_leaves(&self) -> usize {
        self.params.len()
    }

    /// Assemble the input vector for a train-step artifact.
    pub fn step_inputs(&self, tokens: HostTensor, targets: HostTensor) -> Vec<HostTensor> {
        let mut v = Vec::with_capacity(3 * self.params.len() + 3);
        v.extend(self.params.iter().cloned());
        v.extend(self.m.iter().cloned());
        v.push(self.step.clone());
        v.extend(self.v.iter().cloned());
        v.push(tokens);
        v.push(targets);
        v
    }

    /// Consume a train-step artifact's outputs; returns the loss tensor.
    pub fn absorb_step_outputs(&mut self, mut out: Vec<HostTensor>) -> Result<HostTensor> {
        let p = self.params.len();
        let expect = 1 + 3 * p + 1;
        if out.len() != expect {
            bail!("train step returned {} outputs, expected {expect}", out.len());
        }
        let loss = out.remove(0);
        self.params = out.drain(..p).collect();
        self.m = out.drain(..p).collect();
        self.step = out.remove(0);
        self.v = out.drain(..p).collect();
        debug_assert!(out.is_empty());
        Ok(loss)
    }

    /// Validate this state against a train-step artifact signature.
    pub fn check_against(&self, spec: &ArtifactSpec) -> Result<()> {
        let p = self.params.len();
        let want = 3 * p + 3;
        if spec.inputs.len() != want {
            bail!(
                "artifact '{}' has {} inputs; state implies {want}",
                spec.name,
                spec.inputs.len()
            );
        }
        for (i, t) in self.params.iter().enumerate() {
            if !t.matches(&spec.inputs[i]) {
                bail!("param leaf {i} mismatch vs '{}'", spec.name);
            }
        }
        Ok(())
    }

    /// Indices of parameter leaves whose path contains `needle`
    /// (e.g. "pq_q" for codebook patching).
    pub fn find_leaves(&self, needle: &str) -> Vec<usize> {
        self.param_paths
            .iter()
            .enumerate()
            .filter(|(_, p)| p.contains(needle))
            .map(|(i, _)| i)
            .collect()
    }

    /// Replace one parameter leaf (shape-checked).
    pub fn set_leaf(&mut self, idx: usize, t: HostTensor) -> Result<()> {
        let old = self
            .params
            .get(idx)
            .context("leaf index out of range")?;
        if old.shape() != t.shape() || old.dtype() != t.dtype() {
            bail!(
                "leaf {idx} shape/dtype mismatch: {:?} vs {:?}",
                old.shape(),
                t.shape()
            );
        }
        self.params[idx] = t;
        Ok(())
    }

    /// Total bytes held by this state (params + moments).
    pub fn bytes(&self) -> usize {
        self.params.iter().map(HostTensor::bytes).sum::<usize>()
            + self.m.iter().map(HostTensor::bytes).sum::<usize>()
            + self.v.iter().map(HostTensor::bytes).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::DType;

    fn dummy_state(p: usize) -> TrainState {
        let t = |i: usize| HostTensor::f32(vec![2, 2], vec![i as f32; 4]);
        TrainState {
            params: (0..p).map(t).collect(),
            m: (0..p).map(|_| HostTensor::f32(vec![2, 2], vec![0.0; 4])).collect(),
            v: (0..p).map(|_| HostTensor::f32(vec![2, 2], vec![0.0; 4])).collect(),
            step: HostTensor::scalar_i32(0),
            param_paths: (0..p).map(|i| format!("['blocks']['leaf{i}']")).collect(),
        }
    }

    #[test]
    fn step_io_roundtrip() {
        let mut s = dummy_state(3);
        let tokens = HostTensor::i32(vec![1, 4], vec![1, 2, 3, 4]);
        let inputs = s.step_inputs(tokens.clone(), tokens.clone());
        assert_eq!(inputs.len(), 3 * 3 + 3);
        // Fake outputs: loss + bumped state.
        let mut out = vec![HostTensor::scalar_f32(1.5)];
        out.extend((0..3).map(|_| HostTensor::f32(vec![2, 2], vec![9.0; 4]))); // params
        out.extend((0..3).map(|_| HostTensor::f32(vec![2, 2], vec![0.1; 4]))); // m
        out.push(HostTensor::scalar_i32(1));
        out.extend((0..3).map(|_| HostTensor::f32(vec![2, 2], vec![0.2; 4]))); // v
        let loss = s.absorb_step_outputs(out).unwrap();
        assert_eq!(loss.scalar().unwrap(), 1.5);
        assert_eq!(s.params[0].as_f32().unwrap()[0], 9.0);
        assert_eq!(s.step.scalar().unwrap(), 1.0);
        assert_eq!(s.v[2].as_f32().unwrap()[0], 0.2);
    }

    #[test]
    fn absorb_rejects_wrong_arity() {
        let mut s = dummy_state(2);
        assert!(s.absorb_step_outputs(vec![HostTensor::scalar_f32(0.0)]).is_err());
    }

    #[test]
    fn leaf_lookup_and_patch() {
        let mut s = dummy_state(4);
        s.param_paths[2] = "['blocks']['pq_q']".into();
        let found = s.find_leaves("pq_q");
        assert_eq!(found, vec![2]);
        s.set_leaf(2, HostTensor::f32(vec![2, 2], vec![7.0; 4])).unwrap();
        assert_eq!(s.params[2].as_f32().unwrap()[0], 7.0);
        // shape mismatch rejected
        assert!(s.set_leaf(2, HostTensor::f32(vec![4], vec![0.0; 4])).is_err());
        assert!(s
            .set_leaf(9, HostTensor::f32(vec![2, 2], vec![0.0; 4]))
            .is_err());
    }

    #[test]
    fn bytes_accounting() {
        let s = dummy_state(2);
        assert_eq!(s.bytes(), 3 * 2 * 16);
    }
}
