"""PQ quantization kernel vs reference — exact-match + hypothesis sweeps."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pq, ref

SETTINGS = dict(max_examples=4, deadline=None)


def _mk(seed, b, n, m, dsub, e):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (b, n, m * dsub), dtype=jnp.float32)
    cb = pq.init_codebooks(k2, m, e, dsub)
    return x, cb


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 4),
    n=st.sampled_from([8, 17, 64, 128]),
    m=st.sampled_from([1, 2, 4, 8]),
    dsub=st.sampled_from([4, 8, 16]),
    e=st.sampled_from([2, 8, 16, 32]),
)
def test_quantize_matches_ref(seed, b, n, m, dsub, e):
    x, cb = _mk(seed, b, n, m, dsub, e)
    got = pq.pq_quantize(x, cb)
    want = jax.vmap(lambda xx: ref.pq_quantize(xx, cb))(x)
    assert got.shape == (b, n, m)
    assert got.dtype == jnp.int32
    assert bool(jnp.all(got == want))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_quantize_error_matches_ref(seed):
    x, cb = _mk(seed, 2, 32, 4, 8, 16)
    got = pq.pq_quantize_error(x, cb)
    want = jnp.mean(jax.vmap(lambda xx: ref.pq_quantize_error(xx, cb))(x))
    assert jnp.allclose(got, want, rtol=1e-3, atol=1e-5)


def test_codes_in_range():
    x, cb = _mk(3, 2, 64, 4, 8, 16)
    codes = pq.pq_quantize(x, cb)
    assert int(jnp.min(codes)) >= 0
    assert int(jnp.max(codes)) < 16


def test_identical_vectors_get_identical_codes():
    x, cb = _mk(4, 1, 8, 4, 8, 16)
    x = x.at[0, 1].set(x[0, 0])
    codes = pq.pq_quantize(x, cb)
    assert bool(jnp.all(codes[0, 0] == codes[0, 1]))


def test_codeword_vectors_quantize_to_themselves():
    """A vector equal to codeword j in every subspace must map to j."""
    m, e, dsub = 4, 8, 8
    cb = pq.init_codebooks(jax.random.PRNGKey(7), m, e, dsub)
    for j in (0, 3, e - 1):
        v = cb[:, j, :].reshape(1, 1, m * dsub)
        codes = pq.pq_quantize(v, cb)
        assert bool(jnp.all(codes == j)), (j, codes)


def test_codebook_update_reduces_error():
    x, cb = _mk(5, 2, 128, 4, 8, 16)
    e0 = float(pq.pq_quantize_error(x, cb))
    cb2 = pq.pq_codebook_update(x, cb, lr=1.0)
    e1 = float(pq.pq_quantize_error(x, cb2))
    assert e1 < e0, (e0, e1)


def test_codebook_update_matches_ref():
    x, cb = _mk(6, 1, 64, 2, 8, 4)
    got = pq.pq_codebook_update(x, cb, lr=0.5)
    want = ref.pq_codebook_update(x[0], cb, lr=0.5)
    assert jnp.allclose(got, want, atol=1e-5)


def test_codebook_update_keeps_empty_codewords():
    """Codewords that attract no vectors must not move."""
    m, e, dsub = 1, 4, 4
    cb = jnp.stack(
        [jnp.array([[0.0] * 4, [10.0] * 4, [100.0] * 4, [1000.0] * 4])]
    )
    x = jnp.zeros((1, 16, 4)) + 0.1  # everything maps to codeword 0
    cb2 = pq.pq_codebook_update(x, cb, lr=1.0)
    assert jnp.allclose(cb2[0, 1:], cb[0, 1:])
    assert not jnp.allclose(cb2[0, 0], cb[0, 0])


@pytest.mark.parametrize("e", [2, 16, 32])
def test_error_nonnegative(e):
    x, cb = _mk(8, 1, 32, 4, 8, e)
    assert float(pq.pq_quantize_error(x, cb)) >= 0.0
