"""Shared fixtures/helpers for the SPT kernel and model test suite."""

from __future__ import annotations

import jax
import pytest


@pytest.fixture(scope="session", autouse=True)
def _jax_x64_off():
    # All artifacts are f32 (paper: single-precision experiments).
    jax.config.update("jax_enable_x64", False)
    yield


def rngs(seed: int, n: int):
    return jax.random.split(jax.random.PRNGKey(seed), n)
