//! Paper Table 4: MHA/FFN running time + peak memory at different
//! sparsity strengths, for OPT-2048 and LLaMA-4096.
//!
//! Paper shape to reproduce: sparse MHA memory drops with stronger
//! sparsity (1/4 -> 1/8) while its time stays ~LoRA-level; routed FFN
//! time drops near-theoretically with beta (3/4 -> ~1.3x, 1/2 -> ~2x)
//! while its memory barely moves.
//!
//! Default build: module cost vs sparsity measured on the rust-native
//! substrate (8-head workload), with 1-thread and all-thread columns so
//! the cost of each sparsity strength is visible under the parallel
//! path too.  With `--features xla` the original artifact-based module
//! profile also runs.

mod common;

use spt::metrics::{bench, Table};
use spt::sparse::{bspmv, mha, Matrix};
use spt::util::{fmt_bytes, fmt_duration};

fn main() {
    native_table();
    #[cfg(feature = "xla")]
    engine_table();
}

fn native_table() {
    let (w, s) = (common::warmup().max(1), common::samples().max(3));
    let (heads, n, d) = (8usize, 256usize, 64usize);
    let (nt, dff, g) = (512usize, 1024usize, 8usize);
    let threads = *common::thread_counts().last().unwrap();
    let pool1 = common::pool(1);
    let pool_n = common::pool(threads);

    let tn_header = format!("Time ({threads} threads)");
    let mut table = Table::new(
        &format!(
            "Table 4 — module cost vs sparsity on the substrate \
             ({heads} heads, n={n}, d={d}; FFN nt={nt}, D={dff}, G={g})"
        ),
        &[
            "Module",
            "Method",
            "Time (1 thread)",
            tn_header.as_str(),
            "Speedup",
            "Memory / FLOPs",
        ],
    );

    // ---- MHA rows: L = n (dense-equivalent), n/4, n/8 ----
    // One workload; only the sparsity strength varies between rows.
    let mut wl = common::native_workload(heads, n, d, n, nt, dff, g, g / 2);
    for (label, den) in [("spt_l1 (L=n)", 1usize), ("spt_l4", 4), ("spt_l8", 8)] {
        let l = (n / den).max(1);
        wl.mha.l = l;
        let t1 = bench(&format!("mha_{den}_t1"), w, s, || {
            pool1.install(|| {
                std::hint::black_box(wl.mha.forward(&wl.q, &wl.k, &wl.v));
            });
        });
        let tn = bench(&format!("mha_{den}_tn"), w, s, || {
            pool_n.install(|| {
                std::hint::black_box(wl.mha.forward(&wl.q, &wl.k, &wl.v));
            });
        });
        // Attention memory: the CSR the sparse pipeline materializes per
        // head (the paper's O(nL): indptr + L indices + L values per row)
        // vs the dense n^2 map.
        let csr_bytes = (n + 1) * 4 + n * l * 4 + n * l * 4;
        let mem = format!(
            "{} ({} dense)",
            fmt_bytes((csr_bytes * heads) as u64),
            fmt_bytes((n * n * 4 * heads) as u64)
        );
        table.row(&[
            "MHA".into(),
            label.to_string(),
            fmt_duration(t1.median()),
            fmt_duration(tn.median()),
            format!("{:.2}x", t1.median() / tn.median()),
            mem,
        ]);
    }

    // ---- FFN rows: beta = 1, 3/4, 1/2 ----
    let mut rng = spt::util::rng::Rng::new(0x44);
    let x = Matrix::randn(nt, d, 1.0, &mut rng);
    let wi = Matrix::randn(d, dff, 0.2, &mut rng);
    let wo = Matrix::randn(dff, d, 0.2, &mut rng);
    let scores = Matrix::randn(nt, g, 1.0, &mut rng);
    for (label, ga) in [("spt_b1 (dense)", g), ("spt_b34", 3 * g / 4), ("spt_b12", g / 2)] {
        let routing = bspmv::route(&scores, ga);
        let t1 = bench(&format!("ffn_{ga}_t1"), w, s, || {
            pool1.install(|| {
                std::hint::black_box(mha::routed_ffn_par(&x, &wi, &wo, &routing));
            });
        });
        let tn = bench(&format!("ffn_{ga}_tn"), w, s, || {
            pool_n.install(|| {
                std::hint::black_box(mha::routed_ffn_par(&x, &wi, &wo, &routing));
            });
        });
        let frac = bspmv::routed_flops(nt, d, dff, g, ga) as f64
            / bspmv::dense_flops(nt, d, dff) as f64;
        table.row(&[
            "FFN".into(),
            label.to_string(),
            fmt_duration(t1.median()),
            fmt_duration(tn.median()),
            format!("{:.2}x", t1.median() / tn.median()),
            format!("{frac:.2} of dense FLOPs"),
        ]);
    }
    common::emit("table4_substrate", &table);
}

/// The original artifact-based module profile, behind the `xla` feature.
#[cfg(feature = "xla")]
fn engine_table() {
    use spt::coordinator::profile::profile_module;

    let Some(engine) = common::engine_or_skip("table4") else { return };
    let (w, s) = (common::warmup(), common::samples());
    for cfg in ["opt-2048", "llama-4096"] {
        let mut table = Table::new(
            &format!("Table 4 — module cost vs sparsity ({cfg})"),
            &["Module", "Method", "Peak Mem @bs16,seq512", "Duration", "vs lora"],
        );
        for (kind, variants) in [
            ("mha", ["lora", "spt_l4", "spt_l8"].as_slice()),
            ("ffn", ["lora", "spt_b34", "spt_b12"].as_slice()),
        ] {
            let mut lora_time = None;
            for v in variants {
                let name = format!("{kind}_{cfg}_{v}");
                if engine.manifest().get(&name).is_err() {
                    println!("[table4] missing artifact {name}, skipping row");
                    continue;
                }
                let row = profile_module(&engine, kind, cfg, v, w, s)
                    .expect("module profile");
                if *v == "lora" {
                    lora_time = Some(row.time.median());
                }
                table.row(&[
                    kind.to_uppercase(),
                    format!("SPT ({v})").replace("SPT (lora)", "LoRA"),
                    fmt_bytes(row.model_mem_bytes),
                    fmt_duration(row.time.median()),
                    lora_time
                        .map(|t| format!("{:.2}x", t / row.time.median()))
                        .unwrap_or_default(),
                ]);
            }
        }
        common::emit(&format!("table4_{}", cfg.replace('-', "_")), &table);
    }
}
