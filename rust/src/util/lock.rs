//! Pid-file locking for the serve daemon: prevent two daemons from
//! binding the same working directory, and leave a breadcrumb (the pid)
//! for operators.  `O_CREAT|O_EXCL` (`create_new`) makes acquisition
//! atomic on every platform; stale files left by a killed process are
//! reclaimed when their pid is provably gone (Linux `/proc` probe —
//! elsewhere a stale file must be removed by hand, and the error says
//! so).

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// A held pid lock; releases (removes the file) on drop.
#[derive(Debug)]
pub struct PidLock {
    path: PathBuf,
}

impl PidLock {
    /// Acquire the lock at `path`, writing this process's pid into it.
    /// Fails with a clear double-start message when a live owner holds
    /// it; reclaims files whose owner is gone or unreadable.
    pub fn acquire(path: impl AsRef<Path>) -> Result<PidLock> {
        let path = path.as_ref();
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        }
        // Two rounds: the second retries after reclaiming a stale file.
        for round in 0..2 {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(path) {
                Ok(mut f) => {
                    writeln!(f, "{}", std::process::id())
                        .with_context(|| format!("writing pid to {path:?}"))?;
                    return Ok(PidLock { path: path.to_path_buf() });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let owner = std::fs::read_to_string(path)
                        .ok()
                        .and_then(|s| s.trim().parse::<u32>().ok());
                    match owner {
                        Some(pid) if pid_is_live(pid) => bail!(
                            "another spt daemon (pid {pid}) holds {path:?} — \
                             stop it first, or remove the file if that pid is not spt"
                        ),
                        Some(pid) if round == 0 => {
                            crate::log_warn!(
                                "reclaiming stale pid file path={path:?} gone_pid={pid}"
                            );
                            std::fs::remove_file(path).ok();
                        }
                        None if round == 0 => {
                            crate::log_warn!("reclaiming unreadable pid file path={path:?}");
                            std::fs::remove_file(path).ok();
                        }
                        _ => bail!("could not reclaim pid file {path:?}"),
                    }
                }
                Err(e) => return Err(e).with_context(|| format!("creating pid file {path:?}")),
            }
        }
        bail!("could not acquire pid file {path:?}")
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for PidLock {
    fn drop(&mut self) {
        std::fs::remove_file(&self.path).ok();
    }
}

/// Whether `pid` names a live process.  On Linux this is a `/proc`
/// probe; elsewhere we conservatively assume live (a stale file then
/// needs manual removal — the acquire error explains that).
fn pid_is_live(pid: u32) -> bool {
    if cfg!(target_os = "linux") {
        Path::new(&format!("/proc/{pid}")).exists()
    } else {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("spt_lock_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        std::fs::remove_file(&path).ok();
        path
    }

    #[test]
    fn acquire_writes_pid_and_drop_releases() {
        let path = tmp("basic.pid");
        {
            let lock = PidLock::acquire(&path).unwrap();
            assert_eq!(lock.path(), path);
            let body = std::fs::read_to_string(&path).unwrap();
            assert_eq!(body.trim(), std::process::id().to_string());
        }
        assert!(!path.exists(), "drop removes the pid file");
    }

    #[test]
    fn second_acquire_fails_while_owner_lives() {
        let path = tmp("double.pid");
        let _held = PidLock::acquire(&path).unwrap();
        // Our own pid is live, so a second acquire must refuse.
        let err = PidLock::acquire(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("another spt daemon"), "{msg}");
        assert!(msg.contains(&std::process::id().to_string()), "{msg}");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn stale_and_garbage_files_are_reclaimed() {
        let path = tmp("stale.pid");
        // Pid far above any real /proc entry on a test box.
        std::fs::write(&path, "999999999\n").unwrap();
        let _lock = PidLock::acquire(&path).unwrap();
        drop(_lock);
        std::fs::write(&path, "not a pid").unwrap();
        let lock = PidLock::acquire(&path).unwrap();
        drop(lock);
        assert!(!path.exists());
    }
}
