//! Metrics: wall-clock timers with robust statistics, counters, gauges,
//! fixed-bucket histograms, and the table renderer used by every bench
//! target (no `criterion` offline — this module is the measurement
//! harness and the value-telemetry substrate of [`crate::obs`]).

pub mod table;
pub mod timer;

pub use table::Table;
pub use timer::{bench, BenchResult, Stopwatch};

/// Simple monotonically increasing counters keyed by name.
#[derive(Debug, Default)]
pub struct Counters {
    map: std::collections::BTreeMap<String, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, key: &str, v: u64) {
        *self.map.entry(key.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, key: &str) -> u64 {
        self.map.get(key).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.map.iter().map(|(k, &v)| (k.as_str(), v))
    }
}

/// A single instantaneous value (pool occupancy, imbalance ratio, …).
#[derive(Debug, Clone)]
pub struct Gauge {
    pub name: String,
    pub value: f64,
}

impl Gauge {
    pub fn new(name: &str, value: f64) -> Self {
        Gauge { name: name.to_string(), value }
    }
}

/// Fixed-bucket histogram with deterministic bucketing: the bucket
/// boundaries are chosen at construction (ascending upper edges, with
/// an implicit `+Inf` overflow bucket), so two runs observing the same
/// values in any order produce bit-identical counts.  Observation is
/// pure integer bookkeeping — no clocks, no allocation after
/// construction — which is what lets the observability layer aggregate
/// value telemetry without perturbing anything.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub name: String,
    /// Ascending upper bucket edges; a value `v` lands in the first
    /// bucket with `v <= edge`, or the overflow bucket past the last.
    bounds: Vec<f64>,
    /// Per-bucket counts, `bounds.len() + 1` long (last = `+Inf`).
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    /// `bounds` must be finite and strictly ascending.
    pub fn new(name: &str, bounds: &[f64]) -> Self {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        assert!(bounds.iter().all(|b| b.is_finite()), "histogram bounds must be finite");
        Histogram {
            name: name.to_string(),
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    pub fn observe(&mut self, v: f64) {
        let ix = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[ix] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Cumulative counts per bucket (Prometheus `le` semantics): entry
    /// `i` counts observations `<= bounds[i]`; the final entry (`+Inf`)
    /// equals `count()`.
    pub fn cumulative(&self) -> Vec<u64> {
        let mut acc = 0;
        self.counts
            .iter()
            .map(|&c| {
                acc += c;
                acc
            })
            .collect()
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut c = Counters::new();
        c.add("steps", 1);
        c.add("steps", 2);
        c.add("tokens", 512);
        assert_eq!(c.get("steps"), 3);
        assert_eq!(c.get("tokens"), 512);
        assert_eq!(c.get("missing"), 0);
        assert_eq!(c.iter().count(), 2);
    }

    #[test]
    fn histogram_bucketing_is_deterministic_and_order_free() {
        let values = [0.05, 0.1, 0.11, 0.49, 0.5, 0.51, 2.0, -1.0];
        let mut a = Histogram::new("h", &[0.1, 0.5, 1.0]);
        for v in values {
            a.observe(v);
        }
        // Same values, reversed order: identical buckets.
        let mut b = Histogram::new("h", &[0.1, 0.5, 1.0]);
        for v in values.iter().rev() {
            b.observe(*v);
        }
        assert_eq!(a.counts(), b.counts());
        // `le` semantics: boundary values land in their own bucket.
        assert_eq!(a.counts(), &[3, 3, 1, 1]);
        assert_eq!(a.cumulative(), vec![3, 6, 7, 8]);
        assert_eq!(a.count(), 8);
        assert_eq!(*a.cumulative().last().unwrap(), a.count());
        let expected_sum: f64 = values.iter().sum();
        assert!((a.sum() - expected_sum).abs() < 1e-12);
    }

    #[test]
    fn histogram_empty_and_mean() {
        let h = Histogram::new("h", &[1.0]);
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.cumulative(), vec![0, 0]);
        let mut h = Histogram::new("h", &[1.0]);
        h.observe(0.5);
        h.observe(1.5);
        assert_eq!(h.mean(), 1.0);
        assert_eq!(h.counts(), &[1, 1]);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn histogram_rejects_unsorted_bounds() {
        Histogram::new("h", &[1.0, 0.5]);
    }

    #[test]
    fn gauge_holds_value() {
        let g = Gauge::new("occupancy", 0.75);
        assert_eq!(g.name, "occupancy");
        assert_eq!(g.value, 0.75);
    }
}
