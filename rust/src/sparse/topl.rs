//! Bucket-sort top-L selection (paper §5.1, Alg. 3) — the faithful
//! sequential implementation.
//!
//! This is exactly the algorithm the paper runs per GPU thread: M+1 (or
//! M+2 with the causal sentinel) buckets of capacity L, keys inserted in
//! index order, retrieval from the highest bucket down.  The Pallas kernel
//! (`python/compile/kernels/topl.py`) computes the same ranks vectorized;
//! the two are cross-checked in the proptests below and through the
//! goldens round trip.
//!
//! Codes and selections use the flat [`Codes`]/[`TopL`] buffers so the
//! batched multi-head path (`sparse::mha`) can hand disjoint row windows
//! to parallel workers; [`select_into`] is the per-query kernel those
//! workers call directly.

use super::codes::{Codes, TopL};
use super::pq::match_score;

/// Reusable bucket storage for the assign/retrieve phases: flat
/// (M+2) × L slot matrix plus per-bucket fill counts.  One scratch per
/// worker amortizes the allocation across every query row it processes
/// (the old per-query `vec![Vec::new(); m + 2]` dominated the hot path).
#[derive(Debug, Default, Clone)]
pub struct BucketScratch {
    /// `[(m + 2) * l]`, bucket `s` occupies `s * l .. (s + 1) * l`.
    slots: Vec<u32>,
    /// `[m + 2]` entries used per bucket.
    counts: Vec<u32>,
}

/// Select the top-L keys for one query into a preallocated `l`-slot row
/// (paper Alg. 3, single thread), using caller-owned bucket scratch.
///
/// `codes_q`: M codeword ids of the query; `codes_k`: per-key codeword
/// ids.  Writes exactly `l` key indices ordered by (-score, key index).
pub fn select_into(
    codes_q: &[u8],
    codes_k: &Codes,
    l: usize,
    causal_limit: Option<usize>,
    out: &mut [u32],
    scratch: &mut BucketScratch,
) {
    let m = codes_q.len();
    let nk = codes_k.n;
    assert!(l >= 1 && l <= nk);
    assert_eq!(out.len(), l);
    // Buckets[s] holds keys with score s; capacity L each (Alg. 3 line 2).
    let nb = m + 2;
    scratch.slots.resize(nb * l, 0);
    scratch.counts.clear();
    scratch.counts.resize(nb, 0);
    // Assign phase (lines 3-8): keys scanned in ascending index order.
    for (j, ck) in codes_k.rows().enumerate() {
        let s = match causal_limit {
            Some(limit) if j > limit => 0, // sentinel bucket 0 analog
            _ => (match_score(codes_q, ck) + 1) as usize,
        };
        let c = scratch.counts[s] as usize;
        if c < l {
            scratch.slots[s * l + c] = j as u32;
            scratch.counts[s] += 1;
        }
        // Overflow: drop (paper Alg. 3 line 7 instead overwrites the last
        // slot to bound shared memory; keeping the *first* L of a bucket is
        // the same memory bound but preserves the exact
        // (-score, key-index) ranking, matching the Pallas kernel and the
        // sort reference bit-for-bit — required for cross-validation).
    }
    // Retrieve phase (lines 9-16): drain buckets from high score to low.
    let mut filled = 0usize;
    'drain: for s in (0..nb).rev() {
        let cnt = scratch.counts[s] as usize;
        for p in 0..cnt {
            if filled == l {
                break 'drain;
            }
            out[filled] = scratch.slots[s * l + p];
            filled += 1;
        }
    }
    // Under-full rows (causal prefix): pad with unseen smallest indices so
    // the output shape is static, mirroring the kernel's padding slots.
    let mut j = 0u32;
    while filled < l {
        if !out[..filled].contains(&j) {
            out[filled] = j;
            filled += 1;
        }
        j += 1;
    }
}

/// Single-query convenience wrapper over [`select_into`].
pub fn select_one(
    codes_q: &[u8],
    codes_k: &Codes,
    l: usize,
    causal_limit: Option<usize>,
) -> Vec<u32> {
    let mut out = vec![0u32; l];
    let mut scratch = BucketScratch::default();
    select_into(codes_q, codes_k, l, causal_limit, &mut out, &mut scratch);
    out
}

/// Batched selection for all queries of one head (one shared scratch).
pub fn select(codes_q: &Codes, codes_k: &Codes, l: usize, causal: bool) -> TopL {
    let mut out = TopL::zeros(codes_q.n, l);
    let mut scratch = BucketScratch::default();
    for (i, row) in out.data.chunks_exact_mut(l).enumerate() {
        select_into(codes_q.row(i), codes_k, l, causal.then_some(i), row, &mut scratch);
    }
    out.debug_validate(codes_k.n);
    out
}

/// Reference ranking ("sort by (-score, index), take L") used to verify the
/// bucket implementation in tests.
pub fn select_by_sort(
    codes_q: &[u8],
    codes_k: &Codes,
    l: usize,
    causal_limit: Option<usize>,
) -> Vec<u32> {
    let mut scored: Vec<(i64, u32)> = codes_k
        .rows()
        .enumerate()
        .map(|(j, ck)| {
            let s = match causal_limit {
                Some(limit) if j > limit => -1,
                _ => match_score(codes_q, ck) as i64,
            };
            (s, j as u32)
        })
        .collect();
    scored.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    scored.into_iter().take(l).map(|(_, j)| j).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    fn random_codes(
        g: &mut crate::util::proptest::Gen,
        n: usize,
        m: usize,
        e: usize,
    ) -> Codes {
        let mut c = Codes::zeros(n, m);
        for x in c.data.iter_mut() {
            *x = u8::try_from(g.usize_in(0, e - 1)).unwrap();
        }
        c
    }

    #[test]
    fn matches_sort_reference_non_causal() {
        check(100, |g| {
            let n = g.usize_in(2, 64);
            let m = g.usize_in(1, 8);
            let e = g.usize_in(2, 8);
            let l = g.usize_in(1, n);
            let cq = random_codes(g, 1, m, e);
            let ck = random_codes(g, n, m, e);
            let got = select_one(cq.row(0), &ck, l, None);
            let want = select_by_sort(cq.row(0), &ck, l, None);
            prop_assert(got == want, format!("got {got:?} want {want:?}"))
        });
    }

    #[test]
    fn causal_never_selects_future_when_enough_history() {
        check(50, |g| {
            let n = g.usize_in(8, 48);
            let cq = random_codes(g, n, 4, 4);
            let ck = random_codes(g, n, 4, 4);
            let l = g.usize_in(1, 4);
            let sel = select(&cq, &ck, l, true);
            for (i, row) in sel.rows().enumerate() {
                if i + 1 >= l {
                    // enough eligible keys: all selections must be <= i
                    for &j in row {
                        prop_assert(
                            (j as usize) <= i,
                            format!("row {i} selected future key {j}"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn output_is_unique_and_in_range() {
        check(50, |g| {
            let n = g.usize_in(2, 40);
            let l = g.usize_in(1, n);
            let cq = random_codes(g, 1, 6, 3);
            let ck = random_codes(g, n, 6, 3);
            let got = select_one(cq.row(0), &ck, l, None);
            prop_assert(got.len() == l, "wrong length")?;
            let mut sorted = got.clone();
            sorted.sort_unstable();
            sorted.dedup();
            prop_assert(sorted.len() == l, "duplicates")?;
            prop_assert(
                got.iter().all(|&j| (j as usize) < n),
                "out of range",
            )
        });
    }

    #[test]
    fn exact_match_ranks_first() {
        let cq = vec![3u8, 1, 4, 1];
        let mut rows = vec![vec![0u8, 0, 0, 0]; 10];
        rows[7] = cq.clone();
        let ck = Codes::from_rows(&rows);
        let got = select_one(&cq, &ck, 3, None);
        assert_eq!(got[0], 7);
    }

    #[test]
    fn ties_break_by_index() {
        let cq = vec![0u8; 4];
        let ck = Codes::from_rows(&vec![vec![1u8; 4]; 6]); // all score 0
        assert_eq!(select_one(&cq, &ck, 4, None), vec![0, 1, 2, 3]);
    }

    #[test]
    fn causal_prefix_padding_is_well_formed() {
        let cq = Codes::zeros(4, 4);
        let ck = Codes::zeros(4, 4);
        let sel = select(&cq, &ck, 3, true);
        // Row 0 has one eligible key; padding must still give 3 unique ids.
        assert_eq!(sel.row(0).len(), 3);
        let mut s = sel.row(0).to_vec();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 3);
        assert_eq!(sel.row(0)[0], 0); // the eligible key leads
    }

    #[test]
    fn batched_select_matches_per_row_kernel() {
        check(30, |g| {
            let n = g.usize_in(2, 24);
            let l = g.usize_in(1, n);
            let causal = g.bool();
            let cq = random_codes(g, n, 4, 4);
            let ck = random_codes(g, n, 4, 4);
            let batched = select(&cq, &ck, l, causal);
            for i in 0..n {
                let one = select_one(cq.row(i), &ck, l, causal.then_some(i));
                prop_assert(
                    batched.row(i) == one.as_slice(),
                    format!("row {i}: {:?} != {:?}", batched.row(i), one),
                )?;
            }
            Ok(())
        });
    }
}
