//! BSR-mask alternative for the routed FFN (paper §5.2 + Table 6).
//!
//! The rejected design the paper compares against: materialize a per-token
//! block mask over the weight matrix and run masked dense computation.
//! The paper reports this OOMs at [16, 512] tokens (masks ~200 GB expanded
//! to weight shape); we reproduce the *accounting* exactly and provide a
//! runnable small-scale implementation for the Table 6 bench.

use super::bspmv::Routing;
use super::matrix::Matrix;

/// Bytes needed for expanded per-token weight masks — the quantity that
/// explodes (paper: "the BSR masks take up 200GB").
///
/// Each token needs its own masked copy/mask of W_I (d x D) and W_O (D x d)
/// at elementwise granularity for the naive masked-GEMM formulation.
pub fn expanded_mask_bytes(nt: usize, d: usize, dd: usize) -> u64 {
    2 * (nt as u64) * (d as u64) * (dd as u64) * 4
}

/// Bytes for the compressed BSR block-index representation itself,
/// O(nt * n_blocks) (paper §5.2: "BSR requires O(n B) space").
pub fn bsr_index_bytes(nt: usize, g: usize) -> u64 {
    (nt as u64) * (g as u64) * 4 + (nt as u64 + 1) * 4
}

/// Masked-dense routed FFN: per token, zero out the non-activated weight
/// blocks and run the dense math.  Numerically identical to BSpMV; used
/// only at small scale to demonstrate the cost asymmetry.
pub fn routed_ffn_bsr(
    x: &Matrix,
    w_i: &Matrix,
    w_o: &Matrix,
    routing: &Routing,
) -> Matrix {
    let nt = x.rows;
    let d = x.cols;
    let dd = w_i.cols;
    let g = routing.g;
    let dg = dd / g;
    let mut y = Matrix::zeros(nt, d);
    // Per token: build masked weight copies (the wasteful step), multiply.
    for t in 0..nt {
        let mut wi_t = w_i.clone(); // the per-token duplication the paper
        let mut wo_t = w_o.clone(); // calls "a high overhead"
        for gi in 0..g {
            let gate = routing.gate[t][gi];
            for r in 0..d {
                for c in gi * dg..(gi + 1) * dg {
                    *wi_t.at_mut(r, c) *= if routing.mask[t][gi] { 1.0 } else { 0.0 };
                }
            }
            for r in gi * dg..(gi + 1) * dg {
                for c in 0..d {
                    // fold the gate into W_O so h*gate@W_O == h@(gate*W_O)
                    *wo_t.at_mut(r, c) *= gate;
                }
            }
        }
        let xrow = Matrix::from_vec(1, d, x.row(t).to_vec());
        let yrow = xrow.matmul(&wi_t).relu().matmul(&wo_t);
        y.row_mut(t).copy_from_slice(yrow.row(0));
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::bspmv;
    use crate::util::rng::Rng;

    #[test]
    fn bsr_matches_bspmv_numerically() {
        let mut rng = Rng::new(1);
        let (nt, d, dd, g, ga) = (6, 4, 8, 4, 2);
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, dd, 0.4, &mut rng);
        let wo = Matrix::randn(dd, d, 0.4, &mut rng);
        let scores = Matrix::randn(nt, g, 1.0, &mut rng);
        let routing = bspmv::route(&scores, ga);
        let y_bsr = routed_ffn_bsr(&x, &wi, &wo, &routing);
        let y_bspmv = bspmv::routed_ffn(&x, &wi, &wo, &routing);
        assert!(
            y_bsr.max_abs_diff(&y_bspmv) < 1e-4,
            "{}",
            y_bsr.max_abs_diff(&y_bspmv)
        );
    }

    #[test]
    fn paper_scale_mask_bytes_explode() {
        // Paper's failing configuration: tokens [16, 512], OPT-2048 FFN.
        let nt = 16 * 512;
        let bytes = expanded_mask_bytes(nt, 2048, 8192);
        // ~1.1 TB at elementwise f32 duplication; the paper quotes 200GB
        // for its (coarser, block-level) variant — either way far beyond
        // a 24 GB GPU.  Assert the order of magnitude.
        assert!(bytes > 200_000_000_000, "{bytes}");
        // Whereas the BSR *index* alone is tiny, and BSpMV needs no masks.
        assert!(bsr_index_bytes(nt, 8) < 1_000_000);
    }
}
