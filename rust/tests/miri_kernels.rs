//! Pure-kernel tests sized for the Miri CI job
//! (`cargo +nightly miri test --test miri_kernels`).
//!
//! Every test here is single-threaded by construction: all shapes sit
//! below the kernels' parallelism thresholds (`PAR_MATMUL_FLOPS` and
//! friends), so rayon never spawns, and only the sequential
//! cross-validation paths run.  No test touches files, clocks, the
//! environment, or randomness beyond the repo's own seeded [`Rng`] —
//! Miri runs with isolation on.  The same file runs under plain
//! `cargo test` as an ordinary integration suite.

use spt::sparse::bspmv::{self, Routing};
use spt::sparse::pq::{self, Codebooks};
use spt::sparse::topl;
use spt::sparse::{Codes, Csr, Matrix, PackedB};
use spt::util::rng::Rng;

/// Naive triple-loop `A @ B` with ascending-k accumulation — the order
/// the blocked microkernel is documented to reproduce bit-for-bit.
fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let mut out = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut acc = 0.0f32;
            for k in 0..a.cols {
                let av = a.at(i, k);
                if av != 0.0 {
                    acc += av * b.at(k, j);
                }
            }
            *out.at_mut(i, j) = acc;
        }
    }
    out
}

#[test]
fn blocked_gemm_matches_naive_bitwise() {
    let mut rng = Rng::new(11);
    // 13*17*11 multiply-adds: far below the parallel threshold, and odd
    // dims exercise every partial-tile edge of the blocked kernel.
    let a = Matrix::randn(13, 17, 1.0, &mut rng);
    let b = Matrix::randn(17, 11, 1.0, &mut rng);
    let got = a.matmul(&b);
    let want = naive_matmul(&a, &b);
    assert_eq!(got.data, want.data, "blocked GEMM diverged from naive");
}

#[test]
fn register_blocked_tails_match_naive_bitwise() {
    // Shape chosen to drive every edge of the register-blocked kernel
    // under Miri's sequential interpreter: 6 rows = one MR=4 group plus
    // two single-row tails; k = 130 crosses the KC=128 block boundary
    // (accumulators round-trip through `out` between K blocks); n = 27
    // = one 16-wide two-vector tile + one 8-wide tile + a 3-column
    // scalar tail.  6*130*27 multiply-adds stays far below the parallel
    // threshold.
    let mut rng = Rng::new(17);
    let mut a = Matrix::randn(6, 130, 1.0, &mut rng);
    for (i, v) in a.data.iter_mut().enumerate() {
        if i % 5 == 0 {
            *v = 0.0; // exact zeros: the kernel no longer branches on them
        }
    }
    let b = Matrix::randn(130, 27, 1.0, &mut rng);
    let got = a.matmul(&b);
    let want = naive_matmul(&a, &b);
    assert_eq!(got.data, want.data, "register-blocked GEMM diverged");
}

#[test]
fn gemm_nt_both_paths_match_naive_bitwise() {
    // m = 2 runs the per-row dot kernel; m = 6 the transpose-pack +
    // register-blocked path.  Both must equal per-element ascending dots.
    let mut rng = Rng::new(18);
    let b = Matrix::randn(13, 21, 1.0, &mut rng);
    let mut pack = Vec::new();
    for m in [2usize, 6] {
        let a = Matrix::randn(m, 21, 1.0, &mut rng);
        let mut out = vec![0.0f32; m * 13];
        spt::sparse::matrix::gemm_nt_into(
            m, 21, 13, &a.data, &b.data, b.cols, 0, &mut out, &mut pack,
        );
        for i in 0..m {
            for j in 0..13 {
                let mut acc = 0.0f32;
                for (x, y) in a.row(i).iter().zip(b.row(j)) {
                    acc += x * y;
                }
                assert_eq!(out[i * 13 + j], acc, "m={m} element ({i},{j})");
            }
        }
    }
}

#[test]
fn packed_gemm_matches_per_call_packing_bitwise() {
    let mut rng = Rng::new(12);
    let a = Matrix::randn(9, 24, 1.0, &mut rng);
    let b = Matrix::randn(24, 14, 1.0, &mut rng);
    let pb = PackedB::pack(&b);
    assert_eq!(a.matmul_packed(&pb).data, a.matmul(&b).data);
}

#[test]
fn bucket_topl_matches_sort_reference() {
    let mut rng = Rng::new(13);
    let (n, m, e, l) = (24usize, 4usize, 8usize, 6usize);
    let mut codes_q = Codes::zeros(n, m);
    let mut codes_k = Codes::zeros(n, m);
    for c in codes_q.data.iter_mut().chain(codes_k.data.iter_mut()) {
        *c = u8::try_from(rng.below(e)).unwrap();
    }
    for causal in [false, true] {
        let sel = topl::select(&codes_q, &codes_k, l, causal);
        for i in 0..n {
            let want =
                topl::select_by_sort(codes_q.row(i), &codes_k, l, causal.then_some(i));
            // Causal rows shorter than L are padded with arbitrary unseen
            // ids; compare only the genuinely ranked prefix.
            let ranked = if causal { l.min(i + 1) } else { l };
            assert_eq!(
                &sel.row(i)[..ranked],
                &want[..ranked],
                "row {i} causal={causal}"
            );
        }
    }
}

#[test]
fn quantize_append_matches_batch_quantize() {
    let mut rng = Rng::new(14);
    let cb = Codebooks::random(4, 8, 4, &mut rng);
    let x0 = rng.normal_vec(10 * cb.d());
    let x1 = rng.normal_vec(6 * cb.d());
    let mut grown = pq::quantize(&x0, &cb);
    pq::quantize_append(&x1, &cb, &mut grown);
    let mut all = x0;
    all.extend_from_slice(&x1);
    assert_eq!(grown, pq::quantize(&all, &cb));
}

#[test]
fn csr_attention_pipeline_matches_gather_reference() {
    let mut rng = Rng::new(15);
    let (n, dh, l) = (12usize, 8usize, 4usize);
    let q = Matrix::randn(n, dh, 1.0, &mut rng);
    let k = Matrix::randn(n, dh, 1.0, &mut rng);
    let v = Matrix::randn(n, dh, 1.0, &mut rng);
    let sel_rows: Vec<Vec<u32>> = (0..n)
        .map(|i| {
            let mut row = Vec::with_capacity(l);
            let mut j = u32::try_from(i % 3).unwrap();
            while row.len() < l {
                if !row.contains(&j) {
                    row.push(j);
                }
                j = (j + 3) % u32::try_from(n).unwrap();
            }
            row
        })
        .collect();
    let mut csr = Csr::from_rows(&sel_rows, n);
    csr.sddmm(&q, &k);
    csr.softmax_rows();
    let got = csr.spmm(&v);
    // Reference: the same gather/softmax/weighted-sum arithmetic, row by
    // row, in the kernels' own operation order — so equality is bitwise.
    for (i, sel) in sel_rows.iter().enumerate() {
        let mut logits: Vec<f32> = sel
            .iter()
            .map(|&j| {
                q.row(i)
                    .iter()
                    .zip(k.row(j as usize))
                    .map(|(a, b)| a * b)
                    .sum()
            })
            .collect();
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for x in logits.iter_mut() {
            *x = (*x - mx).exp();
            sum += *x;
        }
        for x in logits.iter_mut() {
            *x /= sum.max(1e-30);
        }
        let mut want = vec![0.0f32; dh];
        for (&j, &w) in sel.iter().zip(&logits) {
            if w == 0.0 {
                continue;
            }
            for (o, &x) in want.iter_mut().zip(v.row(j as usize)) {
                *o += w * x;
            }
        }
        assert_eq!(got.row(i), &want[..], "attention row {i}");
    }
}

#[test]
fn routed_ffn_matches_dense_gated_reference() {
    let mut rng = Rng::new(16);
    let (nt, d, g, dg, g_active) = (10usize, 6usize, 4usize, 5usize, 2usize);
    let x = Matrix::randn(nt, d, 1.0, &mut rng);
    let wi = Matrix::randn(d, g * dg, 0.3, &mut rng);
    let wo = Matrix::randn(g * dg, d, 0.3, &mut rng);
    let scores = Matrix::randn(nt, g, 1.0, &mut rng);
    let mut routing = Routing { mask: Vec::new(), gate: Vec::new(), g, g_active };
    bspmv::route_into(&scores, g_active, &mut routing);
    for (t, mrow) in routing.mask.iter().enumerate() {
        assert_eq!(
            mrow.iter().filter(|&&b| b).count(),
            g_active,
            "token {t} selection count"
        );
    }
    let y1 = bspmv::routed_ffn(&x, &wi, &wo, &routing);
    let y2 = bspmv::dense_gated_ffn(&x, &wi, &wo, &routing);
    let diff = y1.max_abs_diff(&y2);
    assert!(diff < 1e-4, "BSpMV vs dense gated FFN diff {diff}");
}
