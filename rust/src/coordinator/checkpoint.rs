//! Crash-safe binary checkpointing of training state (no external
//! format crates: a length-prefixed container with a magic header,
//! version, and per-tensor CRC-32).
//!
//! v3 layout (little-endian):
//! ```text
//! magic "SPTCKPT3" | u32 model_len | model bytes | u8 mode | u32 n_layers
//!                  | u32 n_leaves
//! per leaf: u8 dtype | u32 ndim | u64 dims... | u64 byte_len | payload
//!           | u32 crc32(payload)
//! repeated for: params, m, v, then step (i32)
//! footer: u64 paths_len | paths | u32 crc32(paths)
//! ```
//!
//! v3 adds the per-payload CRC-32 ([`crate::util::crc`]) so bit-flips on
//! disk fail the load with a clear error instead of materializing as
//! silently-wrong weights.  v2 ("SPTCKPT2": identity header, no
//! checksums) and legacy v1 ("SPTCKPT1": neither) still load.
//!
//! **Write protocol (crash safety):** every save goes write-tmp →
//! fsync → rename.  The payload streams into `<name>.tmp` beside the
//! target, is fsynced, and only then renamed over the final path (plus a
//! best-effort directory fsync), so a crash at *any* byte leaves either
//! the complete previous checkpoint or a `.tmp` orphan that loaders and
//! [`find_latest_valid`] ignore — never a torn file under the real
//! name.  Transient write errors are retried with deterministic capped
//! backoff ([`crate::util::retry`]); injected crashes
//! ([`crate::util::fault`], site `ckpt_crash`) abort mid-write exactly
//! like `kill -9`, which is what `tests/crash_safety.rs` exercises.
//!
//! [`find_latest_valid`] scans a checkpoint directory for `*.ckpt`
//! files, skips corrupt/truncated ones with a warning, and returns the
//! newest valid state by step count — the `spt train --auto-resume`
//! entry point.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::state::TrainState;
use crate::config::Mode;
use crate::runtime::HostTensor;
use crate::util::crc::Crc32;
use crate::util::fault::{self, FaultPlan};
use crate::util::retry::{self, Backoff};

const MAGIC_V1: &[u8; 8] = b"SPTCKPT1";
const MAGIC_V2: &[u8; 8] = b"SPTCKPT2";
const MAGIC_V3: &[u8; 8] = b"SPTCKPT3";

/// On-disk format version (v1/v2 are written only by tests; all
/// production saves are v3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Format {
    V1,
    V2,
    V3,
}

/// Model identity embedded in v2/v3 checkpoint headers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CkptMeta {
    pub model: String,
    pub mode: Mode,
    pub n_layers: usize,
}

impl CkptMeta {
    /// Fail with a clear error when this checkpoint does not belong to
    /// the `(model, mode)` the caller is about to run.
    pub fn verify(&self, model: &str, mode: Mode) -> Result<()> {
        if self.model != model || self.mode != mode {
            bail!(
                "checkpoint was trained as model '{}' mode '{}' ({} layers); \
                 requested model '{}' mode '{}' — pass the matching --model/--mode",
                self.model,
                self.mode.as_str(),
                self.n_layers,
                model,
                mode.as_str()
            );
        }
        Ok(())
    }

    /// [`Self::verify`] plus the layer count — for callers about to
    /// materialize a model with a known depth (resume, `spt generate`,
    /// serving), so a depth drift fails here with a clear message
    /// instead of as a leaf-shape mismatch deep in materialization.
    pub fn verify_layers(&self, model: &str, mode: Mode, n_layers: usize) -> Result<()> {
        self.verify(model, mode)?;
        if self.n_layers != n_layers {
            bail!(
                "checkpoint was trained with {} layers; model '{model}' ({}) builds {n_layers} \
                 — pass the preset this checkpoint was trained on",
                self.n_layers,
                mode.as_str()
            );
        }
        Ok(())
    }
}

fn mode_code(mode: Mode) -> u8 {
    match mode {
        Mode::Full => 0,
        Mode::Lora => 1,
        Mode::Spt => 2,
    }
}

fn mode_from_code(code: u8) -> Result<Mode> {
    Ok(match code {
        0 => Mode::Full,
        1 => Mode::Lora,
        2 => Mode::Spt,
        other => bail!("corrupt checkpoint: mode code {other}"),
    })
}

fn write_tensor(w: &mut impl Write, t: &HostTensor, checksum: bool) -> Result<()> {
    let (code, bytes): (u8, Vec<u8>) = match t {
        HostTensor::F32 { data, .. } => {
            (0, data.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        HostTensor::I32 { data, .. } => {
            (1, data.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
    };
    w.write_all(&[code])?;
    let shape = t.shape();
    w.write_all(&(shape.len() as u32).to_le_bytes())?; // det: cast-bounded (ndim <= 16)
    for &d in shape {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    w.write_all(&(bytes.len() as u64).to_le_bytes())?;
    w.write_all(&bytes)?;
    if checksum {
        let mut crc = Crc32::new();
        crc.update(&bytes);
        w.write_all(&crc.finish().to_le_bytes())?;
    }
    Ok(())
}

fn read_tensor(r: &mut impl Read, checksum: bool) -> Result<HostTensor> {
    let mut code = [0u8; 1];
    r.read_exact(&mut code)?;
    let mut ndim = [0u8; 4];
    r.read_exact(&mut ndim)?;
    let ndim = u32::from_le_bytes(ndim) as usize;
    if ndim > 16 {
        bail!("corrupt checkpoint: ndim {ndim}");
    }
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        let mut d = [0u8; 8];
        r.read_exact(&mut d)?;
        shape.push(u64::from_le_bytes(d) as usize);
    }
    let mut len = [0u8; 8];
    r.read_exact(&mut len)?;
    let len = u64::from_le_bytes(len) as usize;
    let expect: usize = shape.iter().product::<usize>() * 4;
    if len != expect {
        bail!("corrupt checkpoint: payload {len} != {expect}");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    if checksum {
        let mut stored = [0u8; 4];
        r.read_exact(&mut stored)?;
        let stored = u32::from_le_bytes(stored);
        let mut crc = Crc32::new();
        crc.update(&payload);
        let computed = crc.finish();
        if computed != stored {
            bail!(
                "corrupt checkpoint: tensor crc mismatch \
                 (stored {stored:#010x}, computed {computed:#010x})"
            );
        }
    }
    Ok(match code[0] {
        0 => HostTensor::f32(
            shape,
            payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        1 => HostTensor::i32(
            shape,
            payload
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        other => bail!("corrupt checkpoint: dtype code {other}"),
    })
}

/// A writer that simulates a mid-write crash: after `crash_after` bytes
/// it refuses further writes with a [`fault::Crash`]-marked I/O error —
/// the on-disk effect of `kill -9` between two `write(2)` calls.
struct FaultWriter<W: Write> {
    inner: W,
    written: u64,
    crash_after: Option<u64>,
}

impl<W: Write> FaultWriter<W> {
    fn new(inner: W, crash_after: Option<u64>) -> Self {
        FaultWriter { inner, written: 0, crash_after }
    }

    fn into_inner(self) -> W {
        self.inner
    }
}

impl<W: Write> Write for FaultWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let allowed = match self.crash_after {
            None => buf.len(),
            Some(limit) => {
                let remain = limit.saturating_sub(self.written);
                if remain == 0 {
                    return Err(std::io::Error::other(fault::Crash {
                        site: "ckpt_crash".into(),
                    }));
                }
                buf.len().min(remain as usize)
            }
        };
        let n = self.inner.write(&buf[..allowed])?;
        self.written += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Save a training state (params + optimizer) in the legacy v1 format
/// (no identity, no checksums) — kept for format-compat tests.  Prefer
/// [`save_tagged`], which stamps identity and per-tensor CRCs.  Still
/// uses the atomic write-tmp → fsync → rename protocol.
pub fn save(state: &TrainState, path: impl AsRef<Path>) -> Result<()> {
    save_with(state, None, path.as_ref(), Format::V1, None)
}

/// Save a training state stamped with its model identity and per-tensor
/// CRC-32 (v3 header), atomically, retrying transient I/O errors.
pub fn save_tagged(state: &TrainState, meta: &CkptMeta, path: impl AsRef<Path>) -> Result<()> {
    save_with(state, Some(meta), path.as_ref(), Format::V3, None)
}

/// [`save_tagged`] with a fault plan threaded through the write path
/// (sites `ckpt_write_err`, `ckpt_crash`, `ckpt_crash_bytes`).
pub fn save_tagged_with(
    state: &TrainState,
    meta: &CkptMeta,
    path: impl AsRef<Path>,
    plan: Option<&FaultPlan>,
) -> Result<()> {
    save_with(state, Some(meta), path.as_ref(), Format::V3, plan)
}

/// v2 writer for backward-compat tests (nothing in production writes
/// v2 anymore).
#[cfg(test)]
fn save_tagged_v2(state: &TrainState, meta: &CkptMeta, path: &Path) -> Result<()> {
    save_with(state, Some(meta), path, Format::V2, None)
}

fn save_with(
    state: &TrainState,
    meta: Option<&CkptMeta>,
    path: &Path,
    fmt: Format,
    plan: Option<&FaultPlan>,
) -> Result<()> {
    retry::retry(&Backoff::default(), &format!("saving checkpoint {path:?}"), |_attempt| {
        save_once(state, meta, path, fmt, plan)
    })
}

/// One atomic save attempt: stream to `<name>.tmp`, fsync, rename over
/// the target, best-effort fsync the directory.  A failure at any point
/// leaves the previous checkpoint (if any) untouched.
fn save_once(
    state: &TrainState,
    meta: Option<&CkptMeta>,
    path: &Path,
    fmt: Format,
    plan: Option<&FaultPlan>,
) -> Result<()> {
    if fault::fire(plan, "ckpt_write_err") {
        return Err(anyhow::Error::from(std::io::Error::other(
            "injected transient write error (fault site ckpt_write_err)",
        )))
        .with_context(|| format!("creating {path:?}"));
    }
    let crash_after = if fault::fire(plan, "ckpt_crash") {
        Some(plan.map(FaultPlan::crash_bytes).unwrap_or(256))
    } else {
        None
    };
    let tmp = tmp_path(path);
    let result = write_and_rename(state, meta, path, &tmp, fmt, crash_after);
    // Clean up the .tmp of an ordinary failure; a simulated crash leaves
    // its torn .tmp on disk, exactly as a real crash would — recovery
    // must cope with the orphan.
    if result.is_err() && crash_after.is_none() {
        std::fs::remove_file(&tmp).ok();
    }
    result.with_context(|| format!("saving checkpoint {path:?}"))
}

fn write_and_rename(
    state: &TrainState,
    meta: Option<&CkptMeta>,
    path: &Path,
    tmp: &Path,
    fmt: Format,
    crash_after: Option<u64>,
) -> Result<()> {
    let file = std::fs::File::create(tmp).with_context(|| format!("creating {tmp:?}"))?;
    let mut w = std::io::BufWriter::new(FaultWriter::new(file, crash_after));
    write_body(&mut w, state, meta, fmt)?;
    w.flush()?;
    let file = w
        .into_inner()
        .map_err(|e| anyhow::anyhow!("flushing {tmp:?}: {e}"))?
        .into_inner();
    file.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
    drop(file);
    std::fs::rename(tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    // Make the rename itself durable where the platform allows it.
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all().ok();
        }
    }
    Ok(())
}

/// Sibling temp path: `dir/name.ckpt` -> `dir/name.ckpt.tmp` (same
/// filesystem, so the final rename is atomic).
fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

fn write_body(
    w: &mut impl Write,
    state: &TrainState,
    meta: Option<&CkptMeta>,
    fmt: Format,
) -> Result<()> {
    match (fmt, meta) {
        (Format::V1, _) | (_, None) => w.write_all(MAGIC_V1)?,
        (fmt, Some(m)) => {
            w.write_all(if fmt == Format::V3 { MAGIC_V3 } else { MAGIC_V2 })?;
            // det: cast-bounded (model name <= 4096 bytes, checked on load)
            w.write_all(&(m.model.len() as u32).to_le_bytes())?;
            w.write_all(m.model.as_bytes())?;
            w.write_all(&[mode_code(m.mode)])?;
            w.write_all(&(m.n_layers as u32).to_le_bytes())?;
        }
    }
    let checksum = fmt == Format::V3 && meta.is_some();
    w.write_all(&(state.params.len() as u32).to_le_bytes())?; // det: cast-bounded (leaves)
    for group in [&state.params, &state.m, &state.v] {
        for t in group {
            write_tensor(w, t, checksum)?;
        }
    }
    write_tensor(w, &state.step, checksum)?;
    // Paths footer for leaf lookup after restore.
    let paths = state.param_paths.join("\n");
    w.write_all(&(paths.len() as u64).to_le_bytes())?;
    w.write_all(paths.as_bytes())?;
    if checksum {
        let mut crc = Crc32::new();
        crc.update(paths.as_bytes());
        w.write_all(&crc.finish().to_le_bytes())?;
    }
    Ok(())
}

/// Restore a training state from disk (any header version), discarding
/// identity metadata.  Use [`load_tagged`] when the caller wants to
/// verify the checkpoint against a run configuration.
pub fn load(path: impl AsRef<Path>) -> Result<TrainState> {
    Ok(load_tagged(path)?.0)
}

/// Restore a training state plus its identity metadata (`None` for
/// legacy v1 checkpoints, which carry none).  v3 files verify every
/// tensor's CRC-32 while reading.
pub fn load_tagged(path: impl AsRef<Path>) -> Result<(TrainState, Option<CkptMeta>)> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let (meta, checksum) = if &magic == MAGIC_V1 {
        (None, false)
    } else if &magic == MAGIC_V2 || &magic == MAGIC_V3 {
        let mut mlen = [0u8; 4];
        r.read_exact(&mut mlen)?;
        let mlen = u32::from_le_bytes(mlen) as usize;
        if mlen > 4096 {
            bail!("corrupt checkpoint: model name length {mlen}");
        }
        let mut mbuf = vec![0u8; mlen];
        r.read_exact(&mut mbuf)?;
        let model = String::from_utf8(mbuf).context("checkpoint model name")?;
        let mut code = [0u8; 1];
        r.read_exact(&mut code)?;
        let mode = mode_from_code(code[0])?;
        let mut nl = [0u8; 4];
        r.read_exact(&mut nl)?;
        (
            Some(CkptMeta { model, mode, n_layers: u32::from_le_bytes(nl) as usize }),
            &magic == MAGIC_V3,
        )
    } else {
        bail!("not an SPT checkpoint (bad magic)");
    };
    let mut n = [0u8; 4];
    r.read_exact(&mut n)?;
    let n = u32::from_le_bytes(n) as usize;
    if n > 1_000_000 {
        bail!("corrupt checkpoint: {n} leaves");
    }
    fn read_group(r: &mut impl Read, n: usize, checksum: bool) -> Result<Vec<HostTensor>> {
        (0..n).map(|_| read_tensor(r, checksum)).collect()
    }
    let params = read_group(&mut r, n, checksum)?;
    let m = read_group(&mut r, n, checksum)?;
    let v = read_group(&mut r, n, checksum)?;
    let step = read_tensor(&mut r, checksum)?;
    let mut plen = [0u8; 8];
    r.read_exact(&mut plen)?;
    let plen = u64::from_le_bytes(plen) as usize;
    if plen > (1 << 26) {
        bail!("corrupt checkpoint: paths footer {plen} bytes");
    }
    let mut pbuf = vec![0u8; plen];
    r.read_exact(&mut pbuf)?;
    if checksum {
        let mut stored = [0u8; 4];
        r.read_exact(&mut stored)?;
        let stored = u32::from_le_bytes(stored);
        let mut crc = Crc32::new();
        crc.update(&pbuf);
        if crc.finish() != stored {
            bail!("corrupt checkpoint: paths footer crc mismatch");
        }
    }
    let param_paths = String::from_utf8(pbuf)?
        .split('\n')
        .map(str::to_string)
        .collect();
    Ok((TrainState { params, m, v, step, param_paths }, meta))
}

/// The newest valid checkpoint in a directory.
#[derive(Debug)]
pub struct LatestCkpt {
    pub path: PathBuf,
    pub state: TrainState,
    pub meta: Option<CkptMeta>,
    pub step: usize,
}

/// Scan `dir` for `*.ckpt` files, skip corrupt/truncated ones with a
/// warning on stderr (and `.tmp` orphans silently — those are torn
/// writes by construction), and return the valid checkpoint with the
/// highest step count (ties: lexicographically last path).  `Ok(None)`
/// when the directory is empty or holds nothing loadable.
pub fn find_latest_valid(dir: impl AsRef<Path>) -> Result<Option<LatestCkpt>> {
    let dir = dir.as_ref();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("scanning checkpoint dir {dir:?}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("ckpt"))
        .collect();
    paths.sort();
    let mut best: Option<LatestCkpt> = None;
    for path in paths {
        let (state, meta) = match load_tagged(&path) {
            Ok(loaded) => loaded,
            Err(e) => {
                crate::log_warn!("skipping corrupt checkpoint path={path:?} err={e:#}");
                continue;
            }
        };
        let step = match state.step.scalar() {
            Ok(s) if s >= 0 => s as usize,
            _ => {
                crate::log_warn!("skipping checkpoint path={path:?} err=unreadable step counter");
                continue;
            }
        };
        // `>=` so a later path wins a step tie (paths are sorted).
        let better = match &best {
            Some(b) => step >= b.step,
            None => true,
        };
        if better {
            best = Some(LatestCkpt { path, state, meta, step });
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> TrainState {
        TrainState {
            params: vec![
                HostTensor::f32(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 9.0, -7.25]),
                HostTensor::i32(vec![2], vec![4, -5]),
            ],
            m: vec![
                HostTensor::f32(vec![2, 3], vec![0.1; 6]),
                HostTensor::i32(vec![2], vec![0, 0]),
            ],
            v: vec![
                HostTensor::f32(vec![2, 3], vec![0.2; 6]),
                HostTensor::i32(vec![2], vec![0, 0]),
            ],
            step: HostTensor::scalar_i32(42),
            param_paths: vec!["['a']".into(), "['b']".into()],
        }
    }

    fn meta() -> CkptMeta {
        CkptMeta { model: "spt-nano-l2".into(), mode: Mode::Spt, n_layers: 2 }
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("spt_ckpt_test").join(name);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip() {
        let dir = tmp_dir("v1_roundtrip");
        let path = dir.join("s.ckpt");
        let s = state();
        save(&s, &path).unwrap();
        let s2 = load(&path).unwrap();
        assert_eq!(s.params, s2.params);
        assert_eq!(s.m, s2.m);
        assert_eq!(s.v, s2.v);
        assert_eq!(s.step, s2.step);
        assert_eq!(s.param_paths, s2.param_paths);
    }

    #[test]
    fn tagged_roundtrip_preserves_meta_and_state() {
        let dir = tmp_dir("v3_roundtrip");
        let path = dir.join("tagged.ckpt");
        let s = state();
        let meta = meta();
        save_tagged(&s, &meta, &path).unwrap();
        // v3 on disk, and no .tmp orphan after a clean save.
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], MAGIC_V3);
        assert!(!tmp_path(&path).exists());
        let (s2, m2) = load_tagged(&path).unwrap();
        assert_eq!(s.params, s2.params);
        assert_eq!(s.step, s2.step);
        assert_eq!(m2.as_ref(), Some(&meta));
        // The untagged loader still reads it.
        let s3 = load(&path).unwrap();
        assert_eq!(s.params, s3.params);
        // verify(): exact match passes, any identity drift fails clearly.
        meta.verify("spt-nano-l2", Mode::Spt).unwrap();
        let err = meta.verify("spt-nano", Mode::Spt).unwrap_err();
        assert!(err.to_string().contains("spt-nano-l2"), "{err}");
        assert!(meta.verify("spt-nano-l2", Mode::Full).is_err());
    }

    #[test]
    fn legacy_v1_loads_with_no_meta() {
        let dir = tmp_dir("v1_legacy");
        let path = dir.join("legacy.ckpt");
        let s = state();
        save(&s, &path).unwrap();
        let (s2, meta) = load_tagged(&path).unwrap();
        assert_eq!(s.params, s2.params);
        assert!(meta.is_none());
    }

    #[test]
    fn legacy_v2_still_loads_and_truncation_errors_cleanly() {
        let dir = tmp_dir("v2_compat");
        let path = dir.join("v2.ckpt");
        let s = state();
        save_tagged_v2(&s, &meta(), &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], MAGIC_V2);
        let (s2, m2) = load_tagged(&path).unwrap();
        assert_eq!(s.params, s2.params);
        assert_eq!(m2, Some(meta()));
        // Mid-tensor truncation on the checksum-free format still fails
        // (read_exact hits EOF) — just without a CRC message.
        std::fs::write(&path, &bytes[..bytes.len() * 3 / 5]).unwrap();
        assert!(load_tagged(&path).is_err());
    }

    #[test]
    fn verify_layers_catches_depth_mismatch() {
        let meta = CkptMeta { model: "spt-nano".into(), mode: Mode::Spt, n_layers: 2 };
        meta.verify_layers("spt-nano", Mode::Spt, 2).unwrap();
        let err = meta.verify_layers("spt-nano", Mode::Spt, 1).unwrap_err();
        assert!(err.to_string().contains("2 layers"), "{err}");
        assert!(err.to_string().contains("builds 1"), "{err}");
        // Model/mode drift still fails through verify()'s message.
        assert!(meta.verify_layers("spt-mini", Mode::Spt, 2).is_err());
    }

    #[test]
    fn detects_truncation_inside_header() {
        let dir = tmp_dir("trunc_header");
        let path = dir.join("trunc_header.ckpt");
        save_tagged(&state(), &meta(), &path).unwrap();
        // Cut mid-way through the model name: magic (8) + name len (4) + 3.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..15]).unwrap();
        assert!(load_tagged(&path).is_err());
    }

    #[test]
    fn rejects_corrupt_mode_code() {
        let dir = tmp_dir("badmode");
        let path = dir.join("badmode.ckpt");
        let meta = CkptMeta { model: "m".into(), mode: Mode::Lora, n_layers: 1 };
        save_tagged(&state(), &meta, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // The mode code sits at magic (8) + name len (4) + name (1).
        bytes[13] = 9;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_tagged(&path).unwrap_err();
        assert!(err.to_string().contains("mode code 9"), "{err}");
    }

    #[test]
    fn rejects_garbage() {
        let dir = tmp_dir("garbage");
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn detects_mid_tensor_truncation() {
        let dir = tmp_dir("trunc_tensor");
        let path = dir.join("trunc.ckpt");
        save_tagged(&state(), &meta(), &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Cut inside the first tensor's payload: the v3 header for
        // model "spt-nano-l2" is 8+4+11+1+4 = 28 bytes, +4 n_leaves,
        // +1 dtype +4 ndim +16 dims +8 len = 61; payload starts at 61.
        std::fs::write(&path, &bytes[..65]).unwrap();
        assert!(load_tagged(&path).is_err());
    }

    #[test]
    fn v3_crc_catches_payload_bit_flip() {
        let dir = tmp_dir("bitflip");
        let path = dir.join("flip.ckpt");
        save_tagged(&state(), &meta(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // First tensor payload (f32 [2,3]) spans bytes 61..85 (see
        // detects_mid_tensor_truncation for the offset arithmetic).
        // A single flipped bit must fail the CRC, not load silently.
        bytes[70] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_tagged(&path).unwrap_err();
        assert!(err.to_string().contains("crc mismatch"), "{err}");
    }

    #[test]
    fn interrupted_save_leaves_previous_checkpoint_intact() {
        let dir = tmp_dir("crash");
        let path = dir.join("s.ckpt");
        let mut s = state();
        save_tagged(&s, &meta(), &path).unwrap();
        let good = std::fs::read(&path).unwrap();
        // Second save crashes mid-write (after 64 bytes).
        s.step = HostTensor::scalar_i32(43);
        let plan = FaultPlan::new().with("ckpt_crash", 1).with("ckpt_crash_bytes", 64);
        let err = save_tagged_with(&s, &meta(), &path, Some(&plan)).unwrap_err();
        assert!(fault::is_crash(&err), "{err:#}");
        // The real path still holds the previous complete checkpoint;
        // the torn bytes live only in the .tmp orphan.
        assert_eq!(std::fs::read(&path).unwrap(), good);
        let torn = tmp_path(&path);
        assert!(torn.exists());
        assert_eq!(std::fs::metadata(&torn).unwrap().len(), 64);
        let (s2, _) = load_tagged(&path).unwrap();
        assert_eq!(s2.step, HostTensor::scalar_i32(42));
    }

    #[test]
    fn transient_write_error_is_retried() {
        let dir = tmp_dir("transient");
        let path = dir.join("s.ckpt");
        let plan = FaultPlan::new().with("ckpt_write_err", 1);
        save_tagged_with(&state(), &meta(), &path, Some(&plan)).unwrap();
        assert_eq!(plan.probes("ckpt_write_err"), 2, "failed once, succeeded once");
        let (s2, _) = load_tagged(&path).unwrap();
        assert_eq!(s2.params, state().params);
    }

    #[test]
    fn find_latest_valid_skips_corruption_and_orphans() {
        let dir = tmp_dir("latest");
        let mut s = state();
        // Steps 10 and 20 saved cleanly; step 30 corrupted afterwards.
        for step in [10, 20, 30] {
            s.step = HostTensor::scalar_i32(step);
            save_tagged(&s, &meta(), &dir.join(format!("step-{step:08}.ckpt"))).unwrap();
        }
        let p30 = dir.join("step-00000030.ckpt");
        let bytes = std::fs::read(&p30).unwrap();
        std::fs::write(&p30, &bytes[..bytes.len() / 2]).unwrap();
        // Plus a torn .tmp orphan and a non-checkpoint file.
        std::fs::write(dir.join("step-00000040.ckpt.tmp"), b"torn").unwrap();
        std::fs::write(dir.join("notes.txt"), b"hi").unwrap();
        let best = find_latest_valid(&dir).unwrap().expect("a valid checkpoint");
        assert_eq!(best.step, 20);
        assert_eq!(best.path, dir.join("step-00000020.ckpt"));
        assert_eq!(best.meta, Some(meta()));
        assert_eq!(best.state.step, HostTensor::scalar_i32(20));

        // An empty directory yields None, a missing one errors.
        let empty = tmp_dir("latest_empty");
        assert!(find_latest_valid(&empty).unwrap().is_none());
        assert!(find_latest_valid(empty.join("nope")).is_err());
    }
}
